"""Import/Export pub-sub between jobs (paper §6.4): microservices built
from streaming applications.

An ingest job publishes its output stream by property; an analytics job
subscribes, can be deployed/removed independently, and starts receiving
tuples as soon as the subscription broker matches it — no reconfiguration
of the producer.

Run:  PYTHONPATH=src python examples/pubsub_microservices.py
"""

import time

from repro.core import wait_for
from repro.platform import Platform


def sink_seen(platform, job):
    for x in platform.pods(job):
        if x.status.get("sink"):
            return x.status["sink"]["seen"]
    return 0


def main() -> None:
    p = Platform(num_nodes=4)
    try:
        print("== deploy the always-running ingest application")
        p.submit("ingest", {"app": {
            "type": "streams", "width": 2, "pipeline_depth": 1,
            "source": {"rate_sleep": 0.001},
            "export": {"stream": "parsed", "properties": {"format": "tuples",
                                                          "team": "analytics"}},
        }})
        assert p.wait_full_health("ingest", 60)

        print("== deploy a subscribing analytics job (by property match)")
        p.submit("analytics", {"app": {
            "type": "streams", "width": 1, "pipeline_depth": 1,
            "pre_ops": 0, "post_ops": 0, "source": {"tuples": 1},
            "import": {"subscription": {"properties": {"team": "analytics"}}},
        }})
        assert p.wait_submitted("analytics", 30)
        assert wait_for(lambda: sink_seen(p, "analytics") > 100, 60)
        print("   analytics received:", sink_seen(p, "analytics"), "tuples")

        print("== remove analytics; ingest keeps running (loose coupling)")
        p.delete_job("analytics")
        p.wait_terminated("analytics", 30)
        time.sleep(0.5)
        assert p.job_status("ingest").get("fullHealth")
        print("   ingest still healthy:", p.job_status("ingest")["fullHealth"])

        print("== redeploy analytics: subscription rematches automatically")
        p.submit("analytics2", {"app": {
            "type": "streams", "width": 1, "pipeline_depth": 1,
            "pre_ops": 0, "post_ops": 0, "source": {"tuples": 1},
            "import": {"subscription": {"stream": "parsed"}},
        }})
        assert wait_for(lambda: sink_seen(p, "analytics2") > 100, 60)
        print("   analytics2 received:", sink_seen(p, "analytics2"), "tuples")
        p.delete_job("ingest")
        p.delete_job("analytics2")
    finally:
        p.shutdown()


if __name__ == "__main__":
    main()
