"""End-to-end driver: data-parallel training under the platform, with
consistent-region checkpointing and a mid-run pod kill.

By default trains the full xlstm-125m config (~165M params) for --steps
steps at --seq tokens — the "train a ~100M model for a few hundred steps"
driver.  Use --small for a quick demo (~30s) on limited CPU.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py --small
      PYTHONPATH=src python examples/fault_tolerant_training.py --steps 200
"""

import argparse
import time

from repro.platform import Platform, crds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-shard", type=int, default=2)
    ap.add_argument("--width", type=int, default=2)
    ap.add_argument("--interval", type=int, default=25)
    ap.add_argument("--small", action="store_true",
                    help="reduced same-family config, ~30s demo")
    ap.add_argument("--kill-at", type=int, default=0,
                    help="kill a trainer once this step commits (0=midpoint)")
    args = ap.parse_args()

    arch: object = args.arch
    if args.small:
        from repro.configs import reduced_config

        arch = reduced_config(args.arch)
        args.steps = min(args.steps, 40)
        args.interval = min(args.interval, 10)
    else:
        from repro.configs import get_config

        arch = get_config(args.arch)
        print(f"training {args.arch}: {arch.param_count()/1e6:.0f}M params, "
              f"{args.steps} steps, seq {args.seq}, dp={args.width}")

    kill_at = args.kill_at or (args.interval * max(1, args.steps // args.interval // 2))
    spec = {
        "app": {"type": "train", "arch": arch, "data_parallel": args.width,
                "steps": args.steps, "batch_per_shard": args.batch_per_shard,
                "seq_len": args.seq, "lr": 3e-3},
        "consistentRegion": {"name": "dp", "interval": args.interval},
    }

    p = Platform(num_nodes=4)
    try:
        t0 = time.time()
        p.submit("train", spec)
        assert p.wait_submitted("train", 60)
        assert p.wait_full_health("train", 120)
        print(f"[{time.time()-t0:6.1f}s] full health; training...")

        killed = False
        last_step = -1
        losses = []
        while True:
            st = p.rest.get_cr_state("train", "dp") or {}
            committed = st.get("lastCommitted", -1)
            ms = p.metrics("train")
            steps = [m.get("step", 0) for m in ms.values()]
            loss = [m.get("loss") for m in ms.values() if "loss" in m]
            if steps and max(steps) != last_step:
                last_step = max(steps)
                if loss:
                    losses.append((last_step, min(loss)))
                print(f"[{time.time()-t0:6.1f}s] step {last_step:4d} "
                      f"loss {min(loss) if loss else float('nan'):8.4f} "
                      f"committed@{committed}")
            if not killed and committed >= kill_at:
                trainer = [x.spec["peId"] for x in p.store.list(crds.PE, "default")
                           if "trainer" in str(x.spec.get("operators"))][0]
                print(f"[{time.time()-t0:6.1f}s] !! killing trainer pe-{trainer} "
                      f"(committed checkpoint @ {committed})")
                p.kill_pod("train", trainer)
                killed = True
            if committed >= args.steps or (steps and max(steps) >= args.steps
                                           and committed >= args.steps - args.interval):
                break
            time.sleep(0.5)
        print(f"[{time.time()-t0:6.1f}s] done: committed@"
              f"{p.rest.get_cr_state('train', 'dp')['lastCommitted']}")
        if len(losses) >= 2:
            print(f"loss: first={losses[0][1]:.4f} last={losses[-1][1]:.4f} "
                  f"({'decreased' if losses[-1][1] < losses[0][1] else 'FLAT'})")
    finally:
        p.delete_job("train")
        p.wait_terminated("train", 30)
        p.shutdown()


if __name__ == "__main__":
    main()
