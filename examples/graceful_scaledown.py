"""Graceful scale-down: shrink a loaded parallel region without tuple loss.

Submits the paper's test app (source -> 2-wide parallel region -> sink) with
a finite source and channels slower than the source, so the region's input
rings hold a real backlog.  Then shrinks the region 2 -> 1 mid-stream: the
retiring channel PEs enter the ``Draining`` state, pull their rings dry
(delivering through the surviving generation), and only then are their pods
deleted.  The sink ends at exactly the emitted tuple count — zero loss —
and the causal trace shows the drain links.

Run:  PYTHONPATH=src python examples/graceful_scaledown.py
"""

import time

from repro.core import wait_for
from repro.platform import Platform

N_TUPLES = 600


def sink_seen(platform, job):
    for pod in platform.pods(job):
        if pod.status.get("sink"):
            return pod.status["sink"]["seen"]
    return 0


def main() -> None:
    platform = Platform(num_nodes=4)
    try:
        print("== submit: finite source, channels slower than the source")
        platform.submit("demo", {
            "app": {"type": "streams", "width": 2, "pipeline_depth": 2,
                    "source": {"tuples": N_TUPLES, "rate_sleep": 0.0005},
                    "channel": {"work_sleep": 0.001},
                    "sink": {"report_every": 10}},
            # the drain block (defaults shown): crds.drain_config
            "drain": {"enabled": True, "timeout": 15.0, "grace": 0.3},
        })
        assert platform.wait_full_health("demo", 60)
        n0 = len(platform.pods("demo"))
        print(f"   full health with {n0} pods")

        wait_for(lambda: sink_seen(platform, "demo") > 50, 30)
        print(f"== scale down 2 -> 1 with {sink_seen(platform, 'demo')} "
              f"of {N_TUPLES} tuples delivered (the rest in flight)")
        t0 = time.monotonic()
        platform.set_width("demo", "par", 1)
        wait_for(lambda: len(platform.pods("demo")) == n0 - 2, 60)
        print(f"   retiring pods drained + deleted in "
              f"{time.monotonic() - t0:.2f}s")

        assert wait_for(lambda: sink_seen(platform, "demo") >= N_TUPLES, 90)
        seen = sink_seen(platform, "demo")
        print(f"== sink saw {seen}/{N_TUPLES} tuples "
              f"({'ZERO LOSS' if seen == N_TUPLES else 'LOSS!'})")
        dropped = platform.job_metrics("demo").get("tuplesDropped", 0)
        print(f"   metrics plane tuplesDropped = {dropped}")

        print("== drain links in the causal trace:")
        for line in platform.trace.chain():
            if ":drain:" in line or ":retire:" in line:
                print("  ", line)

        platform.delete_job("demo")
        assert platform.wait_terminated("demo", 30)
        print("== terminated")
    finally:
        platform.shutdown()


if __name__ == "__main__":
    main()
