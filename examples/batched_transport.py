"""The batched zero-re-resolve data plane (paper Fig. 8's weakest link).

Two views of the same hot path:
1. raw fabric: the per-tuple path vs ``put_many``/``get_many`` batches
   through one TupleQueue, and per-send ``resolve`` vs the epoch-stamped
   ``EndpointCache`` — the control path priced out of the data path;
2. a live streams job, whose PE metric samples now expose the transport
   counters (``avgPullBatch``, ``resolveHits`` / ``resolveMisses`` /
   ``resolveInvalidations``) — near-zero misses while the topology stands
   still is the "zero re-resolve" property, cache invalidations only when
   a peer (re)starts.

Run:  PYTHONPATH=src python examples/batched_transport.py
"""

import threading
import time

from repro.core import wait_for
from repro.platform import Platform
from repro.platform.fabric import EndpointCache, Fabric, TupleQueue


def pump(batch: int, n: int = 50000) -> float:
    """Tuples/sec through one queue at the given batch size."""
    q = TupleQueue(maxsize=4096)

    def consume():
        got = 0
        while got < n:
            got += len(q.get_many(batch, timeout=1.0)) if batch > 1 else \
                (q.get(timeout=1.0) is not None)

    th = threading.Thread(target=consume, daemon=True)
    th.start()
    t0 = time.monotonic()
    buf = []
    for i in range(n):
        if batch == 1:
            q.put({"seq": i})
        else:
            buf.append({"seq": i})
            if len(buf) >= batch:
                q.put_many(buf)
                buf = []
    if buf:
        q.put_many(buf)
    th.join(60.0)
    if th.is_alive():
        raise RuntimeError("consumer stalled (tuples lost or short-counted)")
    return n / (time.monotonic() - t0)


def main() -> None:
    print("== queue hot path: one lock crossing per batch")
    base = pump(1)
    print(f"   per-tuple : {base:10.0f} tuples/s")
    for batch in (16, 64, 256):
        tps = pump(batch)
        print(f"   batch={batch:<4d}: {tps:10.0f} tuples/s  ({tps / base:.0f}x)")

    print("== name resolution: control path off the data path")
    fab = Fabric()
    fab.publish("demo", 1, 0, TupleQueue())
    n = 50000
    t0 = time.monotonic()
    for _ in range(n):
        fab.resolve("demo", 1, 0)
    per_send = (time.monotonic() - t0) / n * 1e6
    cache = EndpointCache(fab)
    t0 = time.monotonic()
    for _ in range(n):
        cache.get("demo", 1, 0)
    cached = (time.monotonic() - t0) / n * 1e6
    print(f"   resolve per send: {per_send:.2f} us   cached: {cached:.2f} us")

    print("== live job: transport counters in the PE metric samples")
    p = Platform(num_nodes=4)
    try:
        p.submit("app", {"app": {"type": "streams", "width": 2,
                                 "pipeline_depth": 1,
                                 "source": {"rate_sleep": 0.0005}}})
        assert p.wait_full_health("app", 60)
        time.sleep(1.0)
        assert wait_for(lambda: len(p.metrics("app")) >= 3, 30)
        for pe_id, m in sorted(p.metrics("app").items()):
            print(f"   pe{pe_id} {m['operator']:>8s}: in={m['tuplesIn']:<6d} "
                  f"out={m['tuplesOut']:<6d} avgPullBatch={m['avgPullBatch']:.1f} "
                  f"resolve hits/misses/inval="
                  f"{m['resolveHits']}/{m['resolveMisses']}/"
                  f"{m['resolveInvalidations']}")
    finally:
        p.shutdown()


if __name__ == "__main__":
    main()
