"""Quickstart: the cloud-native platform in ~60 seconds.

Submits the paper's test application (source -> parallel region -> sink),
watches it reach full health, doubles the parallel-region width at runtime,
kills a PE to demonstrate the restart causal chain, and tears down.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import wait_for
from repro.platform import Platform


def main() -> None:
    platform = Platform(num_nodes=4)
    try:
        print("== submit (kubectl apply -f job.yaml equivalent)")
        platform.submit("demo", {
            "app": {"type": "streams", "width": 2, "pipeline_depth": 2,
                    "source": {"rate_sleep": 0.001}},
        })
        assert platform.wait_submitted("demo", 30)
        print("   state:", platform.job_status("demo")["state"])
        assert platform.wait_full_health("demo", 60)
        print("   full health with", len(platform.pods("demo")), "pods")

        print("== elastic width change: kubectl edit parallelregion (2 -> 4)")
        n0 = len(platform.pods("demo"))
        platform.set_width("demo", "par", 4)
        wait_for(lambda: len(platform.pods("demo")) == n0 + 4, 60)
        assert platform.wait_full_health("demo", 60)
        print("   pods:", n0, "->", len(platform.pods("demo")),
              "(only changed PEs restarted)")

        print("== kill a PE: pod-failure causal chain restarts it")
        platform.kill_pod("demo", 2)
        assert platform.wait_full_health("demo", 60)
        pe = platform.store.get("ProcessingElement", "demo-pe-2")
        print("   pe-2 launchCount:", pe.status["launchCount"])

        time.sleep(1)
        sinks = [x.status.get("sink") for x in platform.pods("demo")
                 if x.status.get("sink")]
        print("== sink progress:", sinks)

        print("== causal chain trace (last 10 entries):")
        for line in platform.trace.chain()[-10:]:
            print("  ", line)

        platform.delete_job("demo")
        assert platform.wait_terminated("demo", 30)
        print("== terminated (foreground cascade deletion)")
    finally:
        platform.shutdown()


if __name__ == "__main__":
    main()
