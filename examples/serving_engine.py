"""Continuous-batching serving of a small model with batched requests.

Demonstrates the serving substrate the decode_32k / long_500k dry-run cells
lower: prefill + per-token batched decode with slot admission/retirement.

Run:  PYTHONPATH=src python examples/serving_engine.py
"""

import time

import jax

from repro.configs import reduced_config
from repro.models import ModelOptions, init_params
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = reduced_config("recurrentgemma-9b")  # hybrid: recurrent + local attn
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"pattern {cfg.block_pattern}")
    params = init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, num_slots=4, max_len=128,
                         opts=ModelOptions(compute_dtype="float32"))
    for rid in range(8):  # 8 requests through 4 slots: continuous batching
        prompt = [1 + rid, 7, 42, (rid * 13) % cfg.vocab_size]
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=12))
    t0 = time.time()
    done = engine.run_until_drained(max_ticks=500)
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s batched greedy decode)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  request {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
