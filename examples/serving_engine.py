"""Continuous-batching serving of a small model with batched requests.

Demonstrates the serving substrate the decode_32k / long_500k dry-run cells
lower: prefill + per-token batched decode with slot admission/retirement,
then the paged engine on the same workload — block-pool KV cache with
banker's admission, chunked prefill interleaved with decode, and prefix
reuse (copy-on-write on divergence) across requests sharing a prompt
prefix.

Run:  PYTHONPATH=src python examples/serving_engine.py
"""

import time

import jax

from repro.configs import reduced_config
from repro.models import ModelOptions, init_params
from repro.serve import PagedServeEngine, Request, ServeEngine


def run(engine, requests, label: str) -> None:
    for rid, prompt in enumerate(requests):
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=12))
    t0 = time.time()
    done = engine.run_until_drained(max_ticks=500)
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"[{label}] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens/dt:.1f} tok/s batched greedy decode)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  request {r.rid}: {r.generated}")


def main() -> None:
    opts = ModelOptions(compute_dtype="float32")

    # hybrid (recurrent + local attn) model through the fixed-slot engine
    cfg = reduced_config("recurrentgemma-9b")
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"pattern {cfg.block_pattern}")
    params = init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, num_slots=4, max_len=128, opts=opts)
    run(engine, [[1 + rid, 7, 42, (rid * 13) % cfg.vocab_size]
                 for rid in range(8)], "fixed-slot")

    # pure-attention model through the paged engine: shared prompt prefixes
    # hit the block-granular prefix cache, divergence is copy-on-write
    cfg = reduced_config("gemma-2b")
    print(f"\nserving {cfg.name} paged: {cfg.param_count()/1e6:.1f}M params")
    params = init_params(jax.random.key(0), cfg)
    # max_active=2: later requests admit after earlier prompts committed
    # their blocks, so the shared prefix is served from the cache
    engine = PagedServeEngine(cfg, params, num_blocks=48, block_size=8,
                              max_active=2, prefill_chunk=8, opts=opts)
    shared = [7, 7, 42, 42, 11, 11, 3, 3]  # common prefix across requests
    run(engine, [shared + [100 + rid] for rid in range(8)], "paged")
    m = engine.metrics()
    print(f"  pool: {m['blocksFree']}/{m['blocksTotal']} blocks free, "
          f"{m['blocksCached']} cached; prefix hit rate "
          f"{m['prefixHitRate']:.0%}; {m['cowCopies']} CoW copies")


if __name__ == "__main__":
    main()
