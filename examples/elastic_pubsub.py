"""Elastic pub-sub: the platform reacts to its own workload.

An ingest job burns through a finite backlog (a consistent region
checkpoints the source offset, so width-change restarts resume instead of
replaying) with channels that are much slower than the source.  A
ScalingPolicy on its parallel region lets the AutoscaleConductor watch the
metrics plane and widen the region — no human edits any spec.  An
analytics job subscribes to the exported stream by property and keeps
receiving tuples across every scaling event (loose coupling).  When the
backlog is done the load vanishes and the same policy shrinks the region
back to minWidth.

Run:  PYTHONPATH=src python examples/elastic_pubsub.py
"""

from repro.core import wait_for
from repro.platform import Platform


def region_state(p, job, region):
    agg = p.job_metrics(job).get("regions", {}).get(region, {})
    return (p.region_width(job, region), agg.get("backpressure", 0.0))


def sink_seen(p, job):
    for x in p.pods(job):
        if x.status.get("sink"):
            return x.status["sink"]["seen"]
    return 0


def main() -> None:
    p = Platform(num_nodes=4)
    try:
        print("== deploy ingest: a 6000-tuple backlog, channels ~250 tuples/s")
        p.submit("ingest", {
            "app": {
                "type": "streams", "width": 1, "pipeline_depth": 1,
                "source": {"tuples": 6000, "rate_sleep": 0.0005},
                "channel": {"work_sleep": 0.004},
                "export": {"stream": "firehose",
                           "properties": {"team": "analytics"}},
            },
            # source offset checkpoints: scale restarts resume, not replay
            "consistentRegion": {"name": "region", "interval": 500},
        })
        assert p.wait_full_health("ingest", 60)
        print("   width=%d backpressure=%.2f" % region_state(p, "ingest", "par"))

        print("== attach a ScalingPolicy; the platform does the rest")
        p.set_scaling_policy("ingest", "par", min_width=1, max_width=3,
                             scale_up_at=0.6, scale_down_at=0.02,
                             cooldown=0.5)
        assert wait_for(lambda: p.region_width("ingest", "par") >= 2, 60)
        w, bp = region_state(p, "ingest", "par")
        print(f"   scaled up: width={w} backpressure={bp:.2f}")

        print("== deploy analytics: subscribes to the stream by property")
        p.submit("analytics", {"app": {
            "type": "streams", "width": 1, "pipeline_depth": 1,
            "pre_ops": 0, "post_ops": 0, "source": {"tuples": 1},
            "import": {"subscription": {"properties": {"team": "analytics"}}},
        }})
        assert wait_for(lambda: sink_seen(p, "analytics") > 100, 60)
        print("   analytics received:", sink_seen(p, "analytics"),
              "tuples while ingest was scaling")

        print("== backlog drains; load vanishes; region shrinks back")
        assert wait_for(lambda: p.region_width("ingest", "par") == 1, 180)
        w, bp = region_state(p, "ingest", "par")
        print(f"   scaled down: width={w} backpressure={bp:.2f}")

        print("== causal chain (autoscale entries):")
        for e in p.trace.chain():
            if e.startswith("autoscale-conductor:scale"):
                print("   ", e)
        print("OK")
    finally:
        p.delete_job("analytics")
        p.delete_job("ingest")
        p.wait_terminated("analytics", 30)
        p.wait_terminated("ingest", 30)
        p.shutdown()


if __name__ == "__main__":
    main()
