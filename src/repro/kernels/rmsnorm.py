"""Fused RMSNorm — Pallas TPU kernel.

One HBM round-trip: read a (block_rows, d) tile, compute the f32 row
moments on the VPU, scale, write.  The XLA path materializes the normalized
intermediate before the scale multiply; fusing removes one full tensor of
HBM traffic per norm site (2 sites per layer).

Layout: x (R, d) — callers flatten leading dims.  Grid (R / block_rows,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x, scale, *, block_rows: int = 256, eps: float = 1e-6,
            interpret: bool = False):
    """x (..., d); scale (d,) -> same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(xf.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
