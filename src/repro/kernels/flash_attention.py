"""Causal GQA flash attention (forward) — Pallas TPU kernel.

TPU-native design (not a CUDA port): the KV axis is the innermost
*sequential* grid dimension; the online-softmax state (m, l, acc) lives in
VMEM scratch that persists across KV grid steps; blocks are MXU-shaped
((block_q, head_dim) x (head_dim, block_k) matmuls with 128-aligned tiles);
fully-masked causal blocks are skipped with ``pl.when`` (grid-step cost
only, no MXU work).  GQA is handled by block-indexing the compact KV array
with ``h // group`` — no KV expansion in memory.

Layouts: q (B, S, H, D); k, v (B, S, KV, D); out (B, S, H, D).
Grid: (B, H, S/block_q, S/block_k), KV innermost (sequential accumulate).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, num_k: int,
                  causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly above the diagonal
    needed = (not causal) or (iq * block_q + block_q - 1 >= ik * block_k)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == num_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        out = acc_ref[...] / l[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)
        lse_ref[0, :, 0] = m_ref[:, 0] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret", "return_lse"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, interpret: bool = False,
                    return_lse: bool = False):
    """q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D) [, lse (B,S,H) f32]."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, num_k=nk, causal=causal)
    grid = (B, H, nq, nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, h, iq, ik: (b, iq, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    if return_lse:
        return out, lse
    return out


# ------------------------------------------------------------- backward


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, scale: float, block_q: int,
                         block_k: int, num_k: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    needed = (not causal) or (iq * block_q + block_q - 1 >= ik * block_k)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, :, 0]
        delta = delta_ref[0, :, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    @pl.when(ik == num_k - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                          block_q: int, block_k: int, num_q: int, causal: bool):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    needed = (not causal) or (iq * block_q + block_q - 1 >= ik * block_k)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, :, 0]
        delta = delta_ref[0, :, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(iq == num_q - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_attention_bwd(q, k, v, out, lse, do, *, block_q: int = 128,
                        block_k: int = 128, causal: bool = True,
                        interpret: bool = False):
    """Backward kernels.  Returns (dq (B,S,H,D), dk, dv (B,S,KV,D)).

    GQA: per-q-head dK/dV partials are produced by the kernel and group-
    summed outside (keeps the kernel free of cross-head accumulation).
    ``delta`` = rowsum(dO ∘ O) is precomputed (the standard two-pass split).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)  # (B,S,H)

    qspec = pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0))
    kspec = pl.BlockSpec((1, block_k, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0))
    rowspec = pl.BlockSpec((1, block_q, 1), lambda b, h, iq, ik: (b, iq, h))

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, num_k=nk, causal=causal),
        grid=(B, H, nq, nk),
        in_specs=[qspec, kspec, kspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dkv grid: kv blocks outer, q blocks inner (sequential accumulate)
    qspec2 = pl.BlockSpec((1, block_q, 1, D), lambda b, h, ik, iq: (b, iq, h, 0))
    kspec2 = pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, iq: (b, ik, h // G, 0))
    outk2 = pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik, iq: (b, ik, h, 0))
    rowspec2 = pl.BlockSpec((1, block_q, 1), lambda b, h, ik, iq: (b, iq, h))
    dk_ph, dv_ph = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, num_q=nq, causal=causal),
        grid=(B, H, nk, nq),
        in_specs=[qspec2, kspec2, kspec2, qspec2, rowspec2, rowspec2],
        out_specs=[outk2, outk2],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dk_ph.reshape(B, S, KV, G, D).sum(axis=3).astype(k.dtype)
    dv = dv_ph.reshape(B, S, KV, G, D).sum(axis=3).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_train(q, k, v, block_q=128, block_k=128, causal=True,
                          interpret=False):
    """Differentiable flash attention (fwd + bwd Pallas kernels)."""
    return flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                           causal=causal, interpret=interpret)


def _fa_fwd(q, k, v, block_q, block_k, causal, interpret):
    out, lse = flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                               causal=causal, interpret=interpret,
                               return_lse=True)
    return out, (q, k, v, out, lse)


def _fa_bwd(block_q, block_k, causal, interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, do, block_q=block_q,
                                     block_k=block_k, causal=causal,
                                     interpret=interpret)
    return dq, dk, dv


flash_attention_train.defvjp(_fa_fwd, _fa_bwd)
