"""GQA decode attention (split-K over the cache) — Pallas TPU kernel.

Decode is memory-bound: one query token per sequence reads the whole KV
cache.  The kernel streams the cache in ``block_k`` slices along the
innermost sequential grid axis (split-K / flash-decoding), keeping online
(m, l, acc) per query-head group in VMEM scratch.  All G query heads of one
KV head are processed together as the matmul M-dimension — the natural MXU
mapping for GQA decode (the q "matrix" is (G, D), the cache block (D, bk)).

Valid-length masking uses the per-sequence ``lengths`` vector, delivered to
SMEM (scalar memory) rather than VMEM: it is control data, not tensor data.

Layouts: q (B, KV, G, D); caches (B, Smax, KV, D); lengths (B, 1) int32.
Grid: (B, KV, Smax/block_k), cache axis innermost (sequential).

``paged_decode_attention`` is the same split-K online-softmax kernel over a
*paged* cache: K/V live in a block pool ``(num_blocks, block_size, KV, D)``
and each sequence names its blocks through a block table delivered as a
scalar-prefetch operand (SMEM, like ``lengths``).  The K/V BlockSpec index
maps read the table, so the gather happens in the DMA engine block by
block — the paged layout is never materialized as a contiguous cache
on-device.  Block 0 of the pool is the engine's scratch block; table rows
of inactive sequences point at it, which is safe because ``lengths`` masks
their output anyway.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._pallas_compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, block_k: int, num_k: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b, 0]
    needed = ik * block_k < length

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == num_k - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512,
                     interpret: bool = False):
    """q (B,H,D); caches (B,Smax,KV,D); lengths (B,) -> (B,H,D)."""
    B, H, D = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_k = min(block_k, Smax)
    if Smax % block_k:
        # arbitrary cache lengths: pad the cache axis up to the next
        # block_k multiple instead of crashing the caller — the padded
        # positions sit beyond every ``lengths`` entry, so the in-kernel
        # valid-length mask already ignores them
        pad = block_k - Smax % block_k
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Smax += pad
    nk = Smax // block_k
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    len2d = lengths.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               num_k=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, whole array
            pl.BlockSpec((1, 1, G, D), lambda b, j, ik: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, j, ik: (b, ik, j, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, j, ik: (b, ik, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, j, ik: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(len2d, qg, k_cache, v_cache)
    return out.reshape(B, H, D)


# ------------------------------------------------------------------- paged


def _paged_decode_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         block_size: int, num_t: int):
    # identical online-softmax body to the dense kernel; only the K/V
    # BlockSpecs differ (they gather through the block table).  tab_ref /
    # len_ref are the scalar-prefetch operands (SMEM).
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b, 0]
    needed = it * block_size < length

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = it * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(it == num_t - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """Decode attention over a paged KV cache.

    q (B,H,D); pools (num_blocks, block_size, KV, D); block_tables (B,T)
    int32 (physical block of each sequence's t-th logical block — unused
    entries must point at a valid block, e.g. scratch block 0); lengths
    (B,) -> (B,H,D).  Split-K runs over the T logical blocks; each grid
    step DMAs one pool block selected by the prefetched table, so no
    contiguous (B, Smax, KV, D) cache ever exists on-device.
    """
    B, H, D = q.shape
    _, block_size, KV, _ = k_pool.shape
    T = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    tab = block_tables.astype(jnp.int32)
    len2d = lengths.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               block_size=block_size, num_t=T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block tables + lengths land in SMEM
        grid=(B, KV, T),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, j, it, tab, lens: (b, j, 0, 0)),
            pl.BlockSpec((1, block_size, 1, D),
                         lambda b, j, it, tab, lens: (tab[b, it], 0, j, 0)),
            pl.BlockSpec((1, block_size, 1, D),
                         lambda b, j, it, tab, lens: (tab[b, it], 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, j, it, tab, lens: (b, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tab, len2d, qg, k_pool, v_pool)
    return out.reshape(B, H, D)
