"""Pallas TPU kernels for the compute hot spots.

Each kernel: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), jitted wrappers in ``ops.py``, pure-jnp oracles in ``ref.py``.
Validated with interpret=True on CPU; the TPU path enables them via
``ops.use_pallas``.
"""

from . import ops, ref
from .decode_attention import decode_attention, paged_decode_attention
from .flash_attention import flash_attention
from .mlstm_chunk import mlstm_chunk
from .rglru_scan import rglru_scan
from .rmsnorm import rmsnorm

__all__ = [
    "decode_attention",
    "flash_attention",
    "mlstm_chunk",
    "ops",
    "paged_decode_attention",
    "ref",
    "rglru_scan",
    "rmsnorm",
]
