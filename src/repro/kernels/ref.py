"""Pure-jnp oracles for every Pallas kernel.

Deliberately the *simplest correct* implementations (quadratic attention
with explicit masks, step-by-step sequential recurrences) — no blocking, no
online softmax — so kernel bugs cannot hide in shared structure.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def causal_attention_ref(q, k, v, *, window: int = 0):
    """q (B,S,H,D); k,v (B,S,KV,D).  Plain masked softmax attention.
    window > 0: sliding-window (local) causal attention."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) / math.sqrt(D)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window:
        mask = mask & (j > i - window)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q (B,H,D); caches (B,Smax,KV,D); lengths (B,)."""
    B, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    kf = jnp.repeat(k_cache, G, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, G, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf) / math.sqrt(D)
    valid = jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """q (B,H,D); pools (N,bs,KV,D); block_tables (B,T); lengths (B,).

    Gathers each sequence's blocks into a contiguous cache and defers to
    the dense oracle — the simplest statement of what paging must equal.
    """
    B = q.shape[0]
    _, bs, KV, D = k_pool.shape
    kc = k_pool[block_tables].reshape(B, -1, KV, D)
    vc = v_pool[block_tables].reshape(B, -1, KV, D)
    return decode_attention_ref(q, kc, vc, lengths)


def rglru_scan_ref(log_a, b):
    """h_t = exp(log_a_t) * h_{t-1} + b_t, sequential.  (B,S,C) f32."""

    def step(h, xs):
        la, bb = xs
        h = jnp.exp(la) * h + bb
        return h, h

    B, S, C = log_a.shape
    h0 = jnp.zeros((B, C), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def mlstm_ref(q, k, v, i_pre, f_pre):
    """Fully sequential stabilized mLSTM.  q,k,v (B,S,H,dk); gates (B,S,H).

    C_t = f C_{t-1} + i k v^T;  h_t = (q C_t) / max(|q n_t|, exp(-m_t)).
    """
    B, S, H, dk = q.shape
    scale = 1.0 / math.sqrt(dk)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    log_i = i_pre.astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs  # (B,H,dk) ... (B,H)
        m_next = jnp.maximum(lf + m, li)
        f_sc = jnp.exp(lf + m - m_next)
        i_sc = jnp.exp(li - m_next)
        C = f_sc[..., None, None] * C + i_sc[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f_sc[..., None] * n + i_sc[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_next))[..., None]
        return (C, n, m_next), h

    C0 = jnp.zeros((B, H, dk, dk), jnp.float32)
    n0 = jnp.zeros((B, H, dk), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3)  # (B,S,H,dk) f32


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
