"""Jitted public wrappers for the Pallas kernels.

``use_pallas(...)`` hooks the kernels into ``ModelOptions`` for the TPU
target path; on CPU everything runs with ``interpret=True`` (correctness
only).  Each op dispatches on availability and falls back to the pure-jnp
reference for unsupported shapes — the module is safe to call anywhere.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention, paged_decode_attention
from .flash_attention import flash_attention, flash_attention_bwd, flash_attention_train
from .mlstm_chunk import mlstm_chunk
from .rglru_scan import rglru_scan
from .rmsnorm import rmsnorm

__all__ = [
    "decode_attention",
    "flash_attention",
    "flash_attention_bwd",
    "flash_attention_train",
    "mlstm_chunk",
    "mlstm_recurrence_op",
    "paged_decode_attention",
    "rglru_scan",
    "rmsnorm",
    "use_pallas",
]


def mlstm_recurrence_op(q, k, v, i_pre, f_pre, *, chunk: int = 64,
                        interpret: bool = False):
    """Drop-in replacement for models.recurrent.mlstm_chunk_recurrence."""
    return mlstm_chunk(q, k, v, i_pre, f_pre, chunk=chunk, interpret=interpret)


def use_pallas(opts, *, interpret: bool = False):
    """Return a ModelOptions with the Pallas kernels wired in (TPU path)."""
    return opts.__class__(
        **{**opts.__dict__,
           "mlstm_recurrence": functools.partial(mlstm_recurrence_op,
                                                 interpret=interpret)})
