"""JAX-version compatibility for Pallas TPU symbols.

Newer JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
the kernels here are written against the new name.  Resolve whichever the
installed JAX provides so the kernels run on both.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
