"""Chunkwise-parallel mLSTM — Pallas TPU kernel.

The xLSTM matrix-memory recurrence in its chunkwise form: the (dk x dk)
state C, the normalizer n, and the stabilizer m live in VMEM scratch and
are carried across the innermost sequential grid axis (chunks); within a
chunk the math is MXU-shaped (two (c x dk) matmuls plus a (c x c) masked
intra-chunk product) — quadratic only inside the chunk, linear across the
sequence.  Mirrors ``repro.models.recurrent.mlstm_chunk_recurrence``; the
oracle is the fully sequential ``ref.mlstm_ref``.

Layouts: q,k,v (BH, S, dk) f32 (batch*heads flattened by the wrapper);
         log_i, log_f (BH, S).  Grid (BH, S/chunk), chunks innermost.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._pallas_compat import CompilerParams

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_ref, n_ref, m_ref, *, chunk: int, scale: float):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (c, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0, :]  # (c,)
    lf = lf_ref[0, :]
    m_prev = m_ref[0, 0]
    C_prev = C_ref[...]
    n_prev = n_ref[0, :]

    csum = jnp.cumsum(lf)  # decay from chunk start to position i
    total = csum[chunk - 1]
    # intra-chunk log weights D[i,j] = csum_i - csum_j + li_j (j <= i)
    D = csum[:, None] - csum[None, :] + li[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(row >= col, D, NEG_INF)
    g = csum + m_prev  # inter-chunk contribution magnitude per position
    m_i = jnp.maximum(jnp.max(D, axis=1), g)  # (c,)
    w_intra = jnp.exp(D - m_i[:, None])
    s_qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    W = s_qk * w_intra
    inter = jnp.exp(g - m_i)  # (c,)
    num = jax.lax.dot_general(W, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    num = num + inter[:, None] * jax.lax.dot_general(
        q, C_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    den = jnp.sum(W, axis=1) + inter * jnp.einsum("cd,d->c", q, n_prev)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, None]
    o_ref[0] = h.astype(o_ref.dtype)

    # carry update to the chunk end
    dec = total - csum + li  # weight of k_j v_j at chunk end
    m_next = jnp.maximum(m_prev + total, jnp.max(dec))
    w_new = jnp.exp(dec - m_next)  # (c,)
    kw = k * w_new[:, None]
    C_ref[...] = jnp.exp(m_prev + total - m_next) * C_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[0, :] = jnp.exp(m_prev + total - m_next) * n_prev + jnp.sum(kw, axis=0)
    m_ref[0, 0] = m_next


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, i_pre, f_pre, *, chunk: int = 64,
                interpret: bool = False):
    """q,k,v (B,S,H,dk); i_pre,f_pre (B,S,H) -> h (B,S,H,dk) f32."""
    B, S, H, dk = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    scale = 1.0 / math.sqrt(dk)
    BH = B * H

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(BH, S, dk).astype(jnp.float32)

    qf, kf, vf = to_bh(q), to_bh(k), to_bh(v)
    li = i_pre.transpose(0, 2, 1).reshape(BH, S).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32)).transpose(0, 2, 1).reshape(BH, S)

    kernel = functools.partial(_mlstm_kernel, chunk=chunk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(BH, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk), lambda b, ic: (b, ic)),
            pl.BlockSpec((1, chunk), lambda b, ic: (b, ic)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dk), lambda b, ic: (b, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dk), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((dk, dk), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf, li, lf)
    return out.reshape(B, H, S, dk).transpose(0, 2, 1, 3)
