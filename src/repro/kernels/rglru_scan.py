"""RG-LRU linear recurrence — time-blocked Pallas TPU kernel.

h_t = exp(log_a_t) * h_{t-1} + b_t, elementwise over channels.

TPU mapping: channels are the lane dimension (128-aligned blocks), batch is
the sublane dimension; time is the innermost *sequential* grid axis with the
carry h held in VMEM scratch across time blocks.  Within a block the scan is
a short unrolled loop of VPU multiply-adds over (block_b, block_c) tiles —
no MXU needed; the kernel exists to keep the recurrence resident in VMEM
instead of bouncing h through HBM per step (the XLA associative-scan path
materializes log-depth intermediates).

Layouts: log_a, b (B, S, C) f32.  Grid (B/bb, C/bc, S/bt), time innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._pallas_compat import CompilerParams


def _rglru_kernel(la_ref, b_ref, o_ref, h_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]
    for j in range(block_t):
        a = jnp.exp(la_ref[:, j, :])
        h = a * h + b_ref[:, j, :]
        o_ref[:, j, :] = h
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_b", "block_c", "block_t",
                                             "interpret"))
def rglru_scan(log_a, b, *, block_b: int = 8, block_c: int = 128,
               block_t: int = 16, interpret: bool = False):
    """log_a, b (B,S,C) f32 -> h (B,S,C) f32."""
    B, S, C = log_a.shape
    block_b = min(block_b, B)
    block_c = min(block_c, C)
    block_t = min(block_t, S)
    assert B % block_b == 0 and C % block_c == 0 and S % block_t == 0
    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b, C // block_c, S // block_t),
        in_specs=[
            pl.BlockSpec((block_b, block_t, block_c),
                         lambda ib, ic, it: (ib, it, ic)),
            pl.BlockSpec((block_b, block_t, block_c),
                         lambda ib, ic, it: (ib, it, ic)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t, block_c),
                               lambda ib, ic, it: (ib, it, ic)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(log_a, b)
