"""Wire format for the cross-process fabric (§8 networking path).

Length-prefixed frames carrying a compact tagged-binary codec for tuple
batches.  The codec is self-contained (no pickle, no third-party deps) and
covers exactly the value shapes the platform moves between PEs: ``None``,
bools, ints, floats, strings, byte payloads, lists/tuples and string-keyed
dicts of the above.  Byte payloads decode as ``memoryview`` slices into the
receive buffer — the zero-copy path for large tuple payloads — while every
container stays a plain Python object so downstream code is agnostic to
which transport delivered it.

Frame layout (network byte order)::

    +--------+--------+--------+------------+=============+
    | magic  | type   | flags  | length     | payload     |
    | u16    | u8     | u8     | u32        | `length` B  |
    +--------+--------+--------+------------+=============+

``FrameDecoder`` is incremental: ``feed()`` accepts arbitrary byte splits
(including mid-header) and yields only complete frames; ``eof()`` raises
``TruncatedFrame`` when the stream dies inside a frame, so a half-decoded
batch can never leak to the consumer.
"""
from __future__ import annotations

import struct

MAGIC = 0x5346  # "SF" — stream frame
HEADER = struct.Struct("!HBBI")
HEADER_SIZE = HEADER.size

# frame types
F_DATA = 1   # tuple-batch delivery (expects an ACK)
F_ACK = 2    # delivery receipt: status + admitted count
F_CTRL = 3   # control-channel RPC envelope
F_HELLO = 4  # worker handshake

DEFAULT_MAX_FRAME = 8 * 1024 * 1024  # generous cap; oversize = protocol error

_I64 = struct.Struct("!q")
_F64 = struct.Struct("!d")
_U32 = struct.Struct("!I")


class FrameError(Exception):
    """Malformed frame: bad magic, oversized length, or corrupt codec."""


class TruncatedFrame(FrameError):
    """Stream ended mid-frame — the tail must be discarded, not decoded."""


# --------------------------------------------------------------- value codec

def _encode_value(obj, out: bytearray) -> None:
    if obj is None:
        out.append(0x4E)  # 'N'
    elif obj is True:
        out.append(0x54)  # 'T'
    elif obj is False:
        out.append(0x46)  # 'F'
    elif isinstance(obj, int):
        if -(2 ** 63) <= obj < 2 ** 63:
            out.append(0x69)  # 'i'
            out += _I64.pack(obj)
        else:  # big int: sign byte + magnitude bytes
            mag = abs(obj)
            raw = mag.to_bytes((mag.bit_length() + 7) // 8, "big")
            out.append(0x49)  # 'I'
            out += _U32.pack(len(raw))
            out.append(1 if obj < 0 else 0)
            out += raw
    elif isinstance(obj, float):
        out.append(0x66)  # 'f'
        out += _F64.pack(obj)
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(0x73)  # 's'
        out += _U32.pack(len(raw))
        out += raw
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        out.append(0x62)  # 'b'
        out += _U32.pack(len(obj))
        out += obj
    elif isinstance(obj, list):
        out.append(0x6C)  # 'l'
        out += _U32.pack(len(obj))
        for v in obj:
            _encode_value(v, out)
    elif isinstance(obj, tuple):
        out.append(0x75)  # 'u'
        out += _U32.pack(len(obj))
        for v in obj:
            _encode_value(v, out)
    elif isinstance(obj, dict):
        out.append(0x64)  # 'd'
        out += _U32.pack(len(obj))
        for k, v in obj.items():
            _encode_value(k, out)
            _encode_value(v, out)
    else:
        raise FrameError(f"unencodable type {type(obj).__name__!r}")


def encode_value(obj) -> bytes:
    out = bytearray()
    _encode_value(obj, out)
    return bytes(out)


def _need(view, off: int, n: int) -> None:
    if off + n > len(view):
        raise FrameError("codec underrun: value extends past frame end")


def _decode_value(view, off: int):
    _need(view, off, 1)
    tag = view[off]
    off += 1
    if tag == 0x4E:
        return None, off
    if tag == 0x54:
        return True, off
    if tag == 0x46:
        return False, off
    if tag == 0x69:
        _need(view, off, 8)
        return _I64.unpack_from(view, off)[0], off + 8
    if tag == 0x49:
        _need(view, off, 5)
        n = _U32.unpack_from(view, off)[0]
        neg = view[off + 4]
        _need(view, off + 5, n)
        val = int.from_bytes(bytes(view[off + 5:off + 5 + n]), "big")
        return (-val if neg else val), off + 5 + n
    if tag == 0x66:
        _need(view, off, 8)
        return _F64.unpack_from(view, off)[0], off + 8
    if tag == 0x73:
        _need(view, off, 4)
        n = _U32.unpack_from(view, off)[0]
        _need(view, off + 4, n)
        return str(view[off + 4:off + 4 + n], "utf-8"), off + 4 + n
    if tag == 0x62:
        _need(view, off, 4)
        n = _U32.unpack_from(view, off)[0]
        _need(view, off + 4, n)
        # zero-copy: a slice of the receive buffer, not a fresh bytes object
        return view[off + 4:off + 4 + n], off + 4 + n
    if tag in (0x6C, 0x75):
        _need(view, off, 4)
        n = _U32.unpack_from(view, off)[0]
        off += 4
        items = []
        for _ in range(n):
            v, off = _decode_value(view, off)
            items.append(v)
        return (tuple(items) if tag == 0x75 else items), off
    if tag == 0x64:
        _need(view, off, 4)
        n = _U32.unpack_from(view, off)[0]
        off += 4
        d = {}
        for _ in range(n):
            k, off = _decode_value(view, off)
            v, off = _decode_value(view, off)
            d[k] = v
        return d, off
    raise FrameError(f"unknown codec tag 0x{tag:02x}")


def decode_value(payload):
    """Decode one value from a frame payload (bytes or memoryview).

    Byte values come back as memoryviews into ``payload`` — keep the
    backing buffer alive as long as the decoded structure is."""
    view = payload if isinstance(payload, memoryview) else memoryview(payload)
    val, off = _decode_value(view, 0)
    if off != len(view):
        raise FrameError(f"trailing garbage: {len(view) - off} bytes")
    return val


# ------------------------------------------------------------------- framing

def encode_frame(ftype: int, payload,
                 max_frame: int = DEFAULT_MAX_FRAME) -> bytes:
    if len(payload) > max_frame:
        raise FrameError(
            f"frame payload {len(payload)} exceeds cap {max_frame}")
    return HEADER.pack(MAGIC, ftype, 0, len(payload)) + bytes(payload)


class FrameDecoder:
    """Incremental frame parser, safe at any byte-split boundary.

    The internal buffer is an immutable ``bytes`` object, so the payload
    memoryviews handed out by ``feed()`` stay valid after later feeds
    (appending builds a new buffer instead of resizing an exported one)."""

    def __init__(self, max_frame: int = DEFAULT_MAX_FRAME):
        self.max_frame = max_frame
        self._buf = b""

    def feed(self, data) -> list:
        """Absorb ``data``; return [(ftype, payload_memoryview), ...] for
        every frame completed by it (possibly none)."""
        buf = bytes(data) if not self._buf else self._buf + bytes(data)
        frames = []
        off = 0
        view = memoryview(buf)
        while len(buf) - off >= HEADER_SIZE:
            magic, ftype, _flags, length = HEADER.unpack_from(buf, off)
            if magic != MAGIC:
                raise FrameError(f"bad magic 0x{magic:04x}")
            if length > self.max_frame:
                raise FrameError(
                    f"frame length {length} exceeds cap {self.max_frame}")
            if len(buf) - off - HEADER_SIZE < length:
                break  # partial frame: wait for more bytes
            start = off + HEADER_SIZE
            frames.append((ftype, view[start:start + length]))
            off = start + length
        self._buf = buf[off:] if off < len(buf) else b""
        return frames

    @property
    def pending(self) -> int:
        """Bytes buffered toward an incomplete frame."""
        return len(self._buf)

    def eof(self) -> None:
        """Stream closed: raise ``TruncatedFrame`` if it died mid-frame."""
        if self._buf:
            raise TruncatedFrame(
                f"stream ended with {len(self._buf)} bytes of partial frame")
