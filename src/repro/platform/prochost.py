"""Cross-process PE hosting: one worker OS process per isolated node.

The paper's platform runs every PE in its own container; the seed repo ran
them all as threads of one process, so the fabric never paid a real
serialization or socket hop.  This module is the bridge between the two:
a node whose spec carries ``processIsolation: true`` gets a **worker
process** (spawned by the kubelet on first use), and every PE bound to
that node runs inside it.

Topology — control plane stays in the parent, data plane goes direct::

    parent process                       worker process (one per node)
    ┌──────────────────────────┐        ┌──────────────────────────┐
    │ operator / kubelet /     │  CTRL  │ WorkerHost               │
    │ HostBridge ──────────────┼────────┼── RpcChannel             │
    │   Fabric (registry,      │ frames │   WorkerFabric (proxy)   │
    │   epochs, partitions)    │        │   PERuntime threads      │
    │   SocketHub (parent PEs) │  DATA  │   SocketHub (rings)      │
    └────────────┬─────────────┘ frames └──────┬───────────────────┘
                 └────── tuple batches ────────┘   (worker ⇄ worker
                                                    flows never touch
                                                    the parent)

- **Control channel** (``RpcChannel``, F_CTRL frames): ``publish`` /
  ``unpublish`` / ``resolve`` / ``set_draining`` / ``partition`` and the
  RestFacade calls are forwarded to the parent, where the single
  authoritative ``Fabric`` registry (epochs, partition windows, residual
  carryover, drain gating) lives — so those semantics hold verbatim across
  the boundary.  Epoch movement is pushed back to workers as casts.
- **Data plane**: each worker runs a ``SocketHub``; its PEs' input rings
  register there, and ``publish`` forwards only the ``(address, token)``
  pair.  A sender in any process resolves to that pair and streams DATA
  frames directly — worker-to-worker traffic never relays through the
  parent.
- **Residual carryover**: a worker draining a PE ships the undelivered
  ring tail back over the control channel (``unpublish`` carries it); the
  parent stashes it like a local residual, and the next ``publish`` of the
  same name returns it for preload — whichever process that incarnation
  lands in.
- **Liveness**: a worker death closes its control channel; the bridge
  marks every endpoint it registered dead and bumps the fabric epoch, so
  ``endpoint_state`` classifies them ``retired`` (fail fast) instead of
  letting partition windows or retry envelopes spin on a process nothing
  can revive.  The pods restart through the normal failure chain and the
  kubelet respawns the worker on demand.

Worker nodes host *streams* PEs only: consistent regions and trainer
collectives need the checkpoint store and ICI group, which stay in-process
(such pods fail their start and stay pending on an isolated node).
"""

from __future__ import annotations

import itertools
import os
import socket
import subprocess
import sys
import threading
import time

from . import crds
from .transport import ShutDown, SocketHub, SocketSender, TupleQueue, \
    Unreachable
from .wire import DEFAULT_MAX_FRAME, F_CTRL, FrameDecoder, FrameError, \
    decode_value, encode_frame, encode_value

_ERR_TYPES = {"unreachable": Unreachable, "timeout": TimeoutError,
              "shutdown": ShutDown, "runtime": RuntimeError}

HANDSHAKE_TIMEOUT = 90.0  # worker import cost (jax) dominates first spawn


def _err_kind(e: Exception) -> str:
    if isinstance(e, Unreachable):
        return "unreachable"
    if isinstance(e, ShutDown):
        return "shutdown"
    if isinstance(e, TimeoutError):
        return "timeout"
    return "runtime"


class RpcChannel:
    """Bidirectional request/reply + cast messaging over one socket.

    Messages are codec dicts ``{id, kind: req|rep|cast, method, body}`` in
    F_CTRL frames.  A reader thread demultiplexes replies to waiting
    requesters and dispatches incoming requests/casts on fresh threads (a
    blocking handler — a 30 s ``resolve`` — must not stall the channel).
    Channel death wakes every waiter with ``Unreachable``.
    """

    def __init__(self, sock: socket.socket, dispatch, name: str = "rpc",
                 on_close=None, max_frame: int = DEFAULT_MAX_FRAME):
        self.sock = sock
        self.dispatch = dispatch  # (method, body, channel) -> reply value
        self.on_close = on_close
        self.max_frame = max_frame
        self.alive = True
        self._send_lock = threading.Lock()
        self._plock = threading.Lock()
        self._pending: dict = {}  # id -> [event, reply-body]
        self._seq = itertools.count(1)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"{name}-reader", daemon=True)
        self._reader.start()

    def _send(self, msg: dict) -> None:
        frame = encode_frame(F_CTRL, encode_value(msg), self.max_frame)
        with self._send_lock:
            self.sock.sendall(frame)

    def request(self, method: str, body=None, timeout: float = 10.0):
        rid = next(self._seq)
        slot = [threading.Event(), None]
        with self._plock:
            self._pending[rid] = slot
        try:
            self._send({"id": rid, "kind": "req", "method": method,
                        "body": body})
        except (OSError, FrameError) as e:
            with self._plock:
                self._pending.pop(rid, None)
            raise Unreachable(f"control send {method}: {e}") from None
        if not slot[0].wait(timeout):
            with self._plock:
                self._pending.pop(rid, None)
            raise Unreachable(f"control rpc {method} timed out")
        rep = slot[1]
        if rep is None:  # channel died while we waited
            raise Unreachable(f"control channel closed during {method}")
        err = rep.get("err")
        if err is not None:
            kind, detail = err
            raise _ERR_TYPES.get(kind, RuntimeError)(detail)
        return rep.get("ok")

    def cast(self, method: str, body=None) -> None:
        try:
            self._send({"id": 0, "kind": "cast", "method": method,
                        "body": body})
        except (OSError, FrameError):
            pass  # fire-and-forget; channel death is handled by the reader

    def _read_loop(self) -> None:
        decoder = FrameDecoder(self.max_frame)
        try:
            while True:
                data = self.sock.recv(65536)
                if not data:
                    return
                for ftype, payload in decoder.feed(data):
                    if ftype == F_CTRL:
                        self._on_message(decode_value(payload))
        except (OSError, FrameError):
            return
        finally:
            self._finalize()

    def _on_message(self, msg: dict) -> None:
        kind = msg.get("kind")
        if kind == "rep":
            with self._plock:
                slot = self._pending.pop(msg["id"], None)
            if slot is not None:
                slot[1] = msg.get("body") or {}
                slot[0].set()
            return
        # req/cast: dispatch off-thread so a blocking handler (resolve)
        # cannot stall replies or other requests
        threading.Thread(target=self._handle, args=(msg,),
                         name="rpc-dispatch", daemon=True).start()

    def _handle(self, msg: dict) -> None:
        method, body, rid = msg.get("method"), msg.get("body"), msg.get("id")
        try:
            result = self.dispatch(method, body, self)
            rep = {"ok": result}
        except Exception as e:  # noqa: BLE001 — typed error travels back
            rep = {"err": [_err_kind(e), f"{type(e).__name__}: {e}"]}
        if msg.get("kind") == "req":
            try:
                self._send({"id": rid, "kind": "rep", "body": rep})
            except (OSError, FrameError):
                pass

    def _finalize(self) -> None:
        self.alive = False
        with self._plock:
            pending, self._pending = self._pending, {}
        for slot in pending.values():
            slot[0].set()  # reply stays None -> waiter raises Unreachable
        try:
            self.sock.close()
        except OSError:
            pass
        if self.on_close is not None:
            self.on_close()

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class RemoteEndpoint:
    """Parent-registry handle for an input ring living in a worker.

    Stands where the ``TupleQueue`` would in ``Fabric._endpoints``: puts
    stream DATA frames to the owning worker's hub, ``closed``/``dead``
    drive the transport-liveness classification, and ``preload`` captures
    residual carryover for the bridge to ship to the worker (the real ring
    preloads there)."""

    def __init__(self, address, token: str, node: str):
        self.address = tuple(address)
        self.token = token
        self.node = node
        self.closed = False
        self.dead = False  # set when the owning worker process dies
        self.pending_residual: list | None = None
        self._sender = SocketSender(self.address, token)

    def put(self, item, timeout: float = 10.0) -> None:
        if self.closed or self.dead:
            raise ShutDown
        self._sender.put(item, timeout)

    def put_many(self, items, timeout: float = 10.0) -> None:
        if self.closed or self.dead:
            raise ShutDown
        self._sender.put_many(items, timeout)

    def preload(self, items) -> None:
        self.pending_residual = list(items)

    def take_all(self) -> list:
        # the worker drains the real ring and ships residuals over the
        # control channel (unpublish); a parent-side direct unpublish of a
        # live worker ring has nothing local to reclaim
        return []

    def close(self) -> None:
        self.closed = True
        self._sender.dispose()

    def __len__(self) -> int:
        return 0


class _WorkerClient:
    """Parent-side record of one worker process."""

    def __init__(self, node: str, channel: RpcChannel, data_addr):
        self.node = node
        self.channel = channel
        self.data_addr = tuple(data_addr)
        self.proc: subprocess.Popen | None = None
        self.pods: set = set()
        self.endpoints: list = []
        self.alive = True

    def start_pod(self, pod_name: str, job: str, pe_id: int, metadata: dict,
                  launch_count: int, standby: bool = False) -> None:
        self.channel.request("start_pod", {
            "pod": pod_name, "job": job, "pe": pe_id, "metadata": metadata,
            "launchCount": launch_count, "standby": standby}, timeout=15.0)
        self.pods.add(pod_name)

    def promote_pod(self, standby_name: str, primary_name: str,
                    launch_count: int) -> bool:
        """Promote a worker-hosted standby: the worker re-keys its pod map
        and wakes the runtime out of its hold under the primary name."""
        rep = self.channel.request("promote_pod", {
            "standby": standby_name, "primary": primary_name,
            "launchCount": launch_count}, timeout=10.0)
        if rep and rep.get("promoted"):
            self.pods.discard(standby_name)
            self.pods.add(primary_name)
            return True
        return False

    def stop_pod(self, pod_name: str, timeout: float = 5.0) -> None:
        self.pods.discard(pod_name)
        self.channel.request("stop_pod", {"pod": pod_name,
                                          "timeout": float(timeout)},
                             timeout=timeout + 5.0)

    def kill_pod(self, pod_name: str) -> bool:
        self.pods.discard(pod_name)
        rep = self.channel.request("kill_pod", {"pod": pod_name},
                                   timeout=10.0)
        return bool(rep and rep.get("killed"))

    def begin_drain(self, pod_name: str, request: dict) -> None:
        self.channel.request("begin_drain", {"pod": pod_name,
                                             "request": request},
                             timeout=10.0)

    def drain_upstream_gone(self, job: str, pe_id: int) -> None:
        self.channel.cast("drain_upstream_gone", {"job": job, "pe": pe_id})


class HostBridge:
    """Parent-side hub for worker processes (the kubelet owns one).

    Accepts worker control connections, answers their fabric/rest RPCs
    against the authoritative registry, pushes epoch movement, exposes
    parent-hosted rings to worker senders through its own data hub, and
    turns a worker death into retired endpoints + failed pods."""

    def __init__(self, fabric, rest, on_pod_exit, on_worker_lost,
                 max_frame: int = DEFAULT_MAX_FRAME):
        self.fabric = fabric
        self.rest = rest
        self.on_pod_exit = on_pod_exit      # (pod, crashed, drain_stats, stopped)
        self.on_worker_lost = on_worker_lost  # (node, [pod names])
        self.max_frame = max_frame
        self.hub = SocketHub(max_frame)  # parent-hosted rings, worker senders
        self._lock = threading.Lock()
        self._workers: dict = {}   # node -> _WorkerClient
        self._awaiting: dict = {}  # node -> threading.Event
        self._hub_tokens: dict = {}  # id(ring) -> token (parent rings exposed)
        self._stop = threading.Event()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(16)
        self.address = self._srv.getsockname()
        threading.Thread(target=self._accept_loop, name="bridge-accept",
                         daemon=True).start()
        threading.Thread(target=self._epoch_loop, name="bridge-epoch",
                         daemon=True).start()

    # ------------------------------------------------------ worker lifecycle

    def ensure_worker(self, node: str) -> _WorkerClient:
        """Return the node's live worker, spawning one if needed (first PE
        on an isolated node pays the process start; later PEs reuse it)."""
        with self._lock:
            client = self._workers.get(node)
            if client is not None and client.alive:
                return client
            event = self._awaiting.setdefault(node, threading.Event())
            event.clear()
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env["REPRO_WORKER_NODE"] = node
        env["REPRO_WORKER_PARENT"] = f"{self.address[0]}:{self.address[1]}"
        proc = subprocess.Popen(
            [sys.executable, "-c",
             "from repro.platform.prochost import worker_main; worker_main()"],
            env=env)
        if not event.wait(HANDSHAKE_TIMEOUT):
            proc.kill()
            raise RuntimeError(f"worker for {node} failed to handshake")
        with self._lock:
            client = self._workers[node]
            client.proc = proc
        return client

    def workers(self) -> dict:
        with self._lock:
            return dict(self._workers)

    def shutdown(self) -> None:
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass
        for client in self.workers().values():
            try:
                client.channel.request("shutdown", timeout=5.0)
            except Exception:  # noqa: BLE001 — it may already be gone
                pass
            client.channel.close()
            if client.proc is not None:
                try:
                    client.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    client.proc.kill()
                    client.proc.wait(timeout=5.0)
        self.hub.close()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            box: list = [None]  # filled by the hello dispatch
            channel = RpcChannel(
                conn,
                lambda method, body, ch, box=box:
                    self._dispatch(box, method, body, ch),
                name="bridge", on_close=lambda box=box:
                    self._worker_gone(box[0]),
                max_frame=self.max_frame)
            del channel  # owned by its reader thread / the client record

    def _worker_gone(self, client: _WorkerClient | None) -> None:
        if client is None or not client.alive:
            return
        client.alive = False
        # dead process: every endpoint it registered is unrevivable — the
        # epoch bump drops sender caches and the next classification sees
        # retired (fail fast), even inside a partition window
        for ep in client.endpoints:
            ep.dead = True
        self.fabric.invalidate()
        with self._lock:
            if self._workers.get(client.node) is client:
                del self._workers[client.node]
        try:
            self.rest.unregister_worker(client.node)
        except Exception:  # noqa: BLE001 — teardown races are benign
            pass
        pods = sorted(client.pods)
        client.pods.clear()
        if pods and not self._stop.is_set():
            self.on_worker_lost(client.node, pods)

    def _epoch_loop(self) -> None:
        last = self.fabric.epoch
        while not self._stop.is_set():
            cur = self.fabric.wait_epoch(last, timeout=0.5)
            if cur == last:
                continue
            last = cur
            for client in self.workers().values():
                client.channel.cast("epoch", {"epoch": cur})

    # ------------------------------------------------------- worker dispatch

    def _dispatch(self, box: list, method: str, body, channel: RpcChannel):
        if method == "hello":
            client = _WorkerClient(body["node"], channel, body["dataAddr"])
            box[0] = client
            with self._lock:
                self._workers[client.node] = client
                event = self._awaiting.get(client.node)
            self.rest.register_worker(client.node, {
                "dataAddr": list(client.data_addr)})
            if event is not None:
                event.set()
            return {"epoch": self.fabric.epoch}
        client = box[0]
        if client is None:
            raise RuntimeError("rpc before hello")
        if method == "publish":
            ep = RemoteEndpoint(client.data_addr, body["token"], client.node)
            self.fabric.publish(body["job"], body["pe"], body["port"], ep)
            client.endpoints.append(ep)
            residuals = ep.pending_residual or []
            ep.pending_residual = None
            return {"epoch": self.fabric.epoch, "residuals": residuals}
        if method == "unpublish":
            residuals = {int(k): v for k, v in
                         (body.get("residuals") or {}).items()}
            self.fabric.unpublish_pe(body["job"], body["pe"],
                                     residuals=residuals)
            return {"epoch": self.fabric.epoch}
        if method == "resolve":
            return self._resolve_for(client, body)
        if method == "set_draining":
            return {"marked": self.fabric.set_draining(body["job"],
                                                       body["pe"]),
                    "epoch": self.fabric.epoch}
        if method == "partition":
            self.fabric.partition(body["job"], body["pe"], body["duration"])
            return {"epoch": self.fabric.epoch}
        if method == "heal":
            return {"healed": self.fabric.heal(body["job"], body["pe"]),
                    "epoch": self.fabric.epoch}
        if method == "endpoint_state":
            return {"state": self.fabric.endpoint_state(body["job"],
                                                        body["pe"])}
        if method == "pe_published":
            return {"published": self.fabric.pe_published(body["job"],
                                                          body["pe"])}
        if method == "publish_count":
            return {"count": self.fabric.publish_count(body["job"],
                                                       body["pe"])}
        if method == "rest":
            name = body["method"]
            if name not in ("notify_connected", "notify_source_done",
                            "report_metrics", "report_sink",
                            "notify_checkpoint", "notify_standby_warm"):
                raise RuntimeError(f"rest method {name!r} not forwarded")
            getattr(self.rest, name)(*body.get("args", []))
            return None
        if method == "rest_req":
            if body["method"] != "get_cr_state":
                raise RuntimeError(f"rest_req {body['method']!r} not allowed")
            return self.rest.get_cr_state(*body.get("args", []))
        if method == "pod_exit":
            pod = body["pod"]
            client.pods.discard(pod)
            self.on_pod_exit(pod, body.get("crashed", False),
                             body.get("drainStats"),
                             body.get("stopped", False))
            return None
        raise RuntimeError(f"unknown bridge rpc {method!r}")

    def _resolve_for(self, client: _WorkerClient, body: dict) -> dict:
        q = self.fabric.resolve(body["job"], body["pe"], body["port"],
                                timeout=body.get("timeout", 30.0),
                                include_draining=body.get("includeDraining",
                                                          False))
        epoch = self.fabric.epoch
        if isinstance(q, RemoteEndpoint):
            if q.node == client.node:
                # co-located: the worker delivers straight into its own ring
                return {"kind": "local", "token": q.token, "epoch": epoch}
            return {"kind": "remote", "addr": list(q.address),
                    "token": q.token, "epoch": epoch}
        # parent-hosted ring: expose it through the bridge's data hub so the
        # worker can stream to it (token registration is idempotent)
        token = self.hub.register(q)
        return {"kind": "remote", "addr": list(self.hub.address),
                "token": token, "epoch": epoch}


# ============================================================== worker side


class WorkerFabric:
    """The fabric surface a PE runtime sees inside a worker process.

    Rings for this worker's own input ports are real local ``TupleQueue``s
    (registered with the worker's data hub); everything about *names* —
    publish, resolve, drain marks, partition windows, restart detection —
    is forwarded to the parent's authoritative registry over the control
    channel.  ``epoch`` is a locally-cached copy advanced by pushes and by
    every reply, so ``EndpointCache`` invalidation behaves exactly as
    in-process (at worst one push-latency behind, which the epoch contract
    already absorbs)."""

    def __init__(self, channel: RpcChannel, hub: SocketHub):
        self.channel = channel
        self.hub = hub
        self.epoch = 0
        self.dns_delay = 0.0  # applied by the parent's resolve
        self._elock = threading.Lock()
        self._local: dict = {}    # (job, pe, port) -> (ring, token)
        self._senders: dict = {}  # (addr, token) -> SocketSender

    def note_epoch(self, epoch) -> None:
        with self._elock:
            if epoch is not None and epoch > self.epoch:
                self.epoch = epoch

    def make_queue(self, maxsize: int = 1024) -> TupleQueue:
        return TupleQueue(maxsize)

    def publish(self, job: str, pe_id: int, port_id: int, q) -> None:
        token = self.hub.register(q)
        rep = self.channel.request("publish", {
            "job": job, "pe": pe_id, "port": port_id, "token": token},
            timeout=15.0)
        if rep.get("residuals"):
            q.preload(rep["residuals"])
        self._local[(job, pe_id, port_id)] = (q, token)
        self.note_epoch(rep.get("epoch"))

    def unpublish_pe(self, job: str, pe_id: int) -> None:
        residuals: dict = {}
        for key in [k for k in self._local if k[:2] == (job, pe_id)]:
            q, token = self._local.pop(key)
            items = q.take_all()
            q.close()
            self.hub.unregister(token)
            if items:
                residuals[key[2]] = items
        rep = self.channel.request("unpublish", {
            "job": job, "pe": pe_id, "residuals": residuals}, timeout=15.0)
        self.note_epoch(rep.get("epoch"))

    def resolve(self, job: str, pe_id: int, port_id: int,
                timeout: float = 30.0, include_draining: bool = False):
        rep = self.channel.request("resolve", {
            "job": job, "pe": pe_id, "port": port_id,
            "timeout": float(timeout), "includeDraining": include_draining},
            timeout=float(timeout) + 10.0)
        self.note_epoch(rep.get("epoch"))
        if rep["kind"] == "local":
            ring = self.hub.lookup(rep["token"])
            if ring is None:
                raise ShutDown("co-located endpoint already retired")
            return ring
        key = (tuple(rep["addr"]), rep["token"])
        sender = self._senders.get(key)
        if sender is None:
            sender = SocketSender(key[0], rep["token"])
            self._senders[key] = sender
        return sender

    def set_draining(self, job: str, pe_id: int) -> int:
        rep = self.channel.request("set_draining",
                                   {"job": job, "pe": pe_id}, timeout=10.0)
        self.note_epoch(rep.get("epoch"))
        return rep.get("marked", 0)

    def partition(self, job: str, pe_id: int, duration: float) -> None:
        rep = self.channel.request("partition", {
            "job": job, "pe": pe_id, "duration": float(duration)},
            timeout=10.0)
        self.note_epoch(rep.get("epoch"))

    def heal(self, job: str, pe_id: int) -> bool:
        rep = self.channel.request("heal", {"job": job, "pe": pe_id},
                                   timeout=10.0)
        self.note_epoch(rep.get("epoch"))
        return bool(rep.get("healed"))

    def endpoint_state(self, job: str, pe_id: int) -> str:
        return self.channel.request("endpoint_state",
                                    {"job": job, "pe": pe_id},
                                    timeout=10.0)["state"]

    def pe_published(self, job: str, pe_id: int) -> bool:
        return bool(self.channel.request("pe_published",
                                         {"job": job, "pe": pe_id},
                                         timeout=10.0)["published"])

    def publish_count(self, job: str, pe_id: int) -> int:
        return int(self.channel.request("publish_count",
                                        {"job": job, "pe": pe_id},
                                        timeout=10.0)["count"])

    def collective(self, job: str, region: str, width: int):
        raise RuntimeError("collectives are unavailable on "
                           "process-isolated nodes")

    def abort_collectives(self, job: str) -> None:
        pass


class WorkerRest:
    """RestFacade proxy: notifications cast to the parent (where the real
    facade throttles, stamps heartbeats — clock-straggle windows included —
    and runs the connect envelope), mirrored-throttled here so the control
    channel never carries per-loop-iteration chatter."""

    def __init__(self, channel: RpcChannel):
        self.channel = channel
        self.ckpt = None  # consistent regions are gated off isolated nodes
        self._last_metric: dict = {}

    def _cast(self, method: str, args: list) -> None:
        self.channel.cast("rest", {"method": method, "args": args})

    def notify_connected(self, job: str, pe_id: int) -> None:
        self._cast("notify_connected", [job, pe_id])

    def notify_source_done(self, job: str, pe_id: int) -> None:
        self._cast("notify_source_done", [job, pe_id])

    def notify_standby_warm(self, job: str, pe_id: int,
                            step: int = -1) -> None:
        self._cast("notify_standby_warm", [job, pe_id, step])

    def report_metrics(self, job: str, pe_id: int, metrics: dict) -> None:
        key = (job, pe_id)
        now = time.monotonic()
        if not metrics.get("final") and \
                now - self._last_metric.get(key, 0.0) < 0.2:
            return
        self._last_metric[key] = now
        self._cast("report_metrics", [job, pe_id, metrics])

    def report_sink(self, job: str, pe_id: int, seen: int,
                    maxseq: int) -> None:
        self._cast("report_sink", [job, pe_id, seen, maxseq])

    def notify_checkpoint(self, job: str, region: str, pe_id: int,
                          step: int) -> None:
        self._cast("notify_checkpoint", [job, region, pe_id, step])

    def get_cr_state(self, job: str, region: str):
        return self.channel.request("rest_req", {
            "method": "get_cr_state", "args": [job, region]}, timeout=10.0)

    def get_routes(self, job: str, op_name: str) -> list:
        return []  # pub/sub import/export stays on in-process nodes

    def routes_epoch(self) -> int:
        return 0


class WorkerHost:
    """Runs inside the worker process: hosts PE runtimes for one node."""

    def __init__(self, sock: socket.socket, node: str,
                 hub: SocketHub | None = None):
        self.node = node
        self.hub = hub if hub is not None else SocketHub()
        self._exit = threading.Event()
        self.channel = RpcChannel(sock, self._dispatch,
                                  name=f"worker-{node}",
                                  on_close=self._exit.set)
        self.fabric = WorkerFabric(self.channel, self.hub)
        self.rest = WorkerRest(self.channel)
        self._plock = threading.Lock()
        self._pods: dict = {}  # pod name -> (runtime, stop_event)

    def hello(self) -> None:
        rep = self.channel.request("hello", {
            "node": self.node, "dataAddr": list(self.hub.address)},
            timeout=15.0)
        self.fabric.note_epoch(rep.get("epoch"))

    def run(self) -> None:
        """Block until the parent orders shutdown or its channel dies (an
        orphaned worker must not outlive the platform)."""
        self.hello()
        self._exit.wait()
        self._stop_all(timeout=2.0)
        self.hub.close()

    # ------------------------------------------------------- parent dispatch

    def _dispatch(self, method: str, body, channel: RpcChannel):
        if method == "start_pod":
            return self._start_pod(body)
        if method == "stop_pod":
            return self._stop_pod(body["pod"], body.get("timeout", 5.0))
        if method == "kill_pod":
            return {"killed": self._stop_pod(body["pod"], 5.0)["existed"]}
        if method == "promote_pod":
            return self._promote_pod(body)
        if method == "begin_drain":
            with self._plock:
                entry = self._pods.get(body["pod"])
            if entry is not None:
                entry[0].begin_drain(body["request"])
            return {"live": entry is not None}
        if method == "drain_upstream_gone":
            with self._plock:
                entries = list(self._pods.values())
            for runtime, _ in entries:
                if runtime.job == body["job"] and runtime.draining:
                    runtime.drain_upstream_gone(body["pe"])
            return None
        if method == "epoch":
            self.fabric.note_epoch(body.get("epoch"))
            return None
        if method == "shutdown":
            # reply first (return value), then unblock run() to exit
            threading.Timer(0.05, self._exit.set).start()
            return None
        if method == "ping":
            return {"node": self.node, "pods": len(self._pods)}
        raise RuntimeError(f"unknown worker rpc {method!r}")

    def _start_pod(self, body: dict):
        from .runtime import PERuntime  # deferred: jax import is heavy
        meta = body["metadata"]
        if meta.get("consistentRegion") or any(
                op.get("kind") == "trainer"
                for op in meta.get("operators", [])):
            raise RuntimeError(
                "process-isolated nodes host streams PEs only (consistent "
                "regions / trainers need the in-process checkpoint+ICI path)")
        stop = threading.Event()
        standby = bool(body.get("standby"))
        runtime = PERuntime(
            job=body["job"], pe_id=body["pe"], metadata=meta,
            fabric=self.fabric, rest=self.rest,
            launch_count=body.get("launchCount", 0), stop_event=stop,
            on_exit=self._on_runtime_exit, standby=standby,
            pod_name=body["pod"] if standby else None)
        with self._plock:
            self._pods[body["pod"]] = (runtime, stop)
        runtime.start()
        return None

    def _stop_pod(self, pod_name: str, timeout: float) -> dict:
        with self._plock:
            entry = self._pods.pop(pod_name, None)
        if entry is None:
            return {"existed": False}
        runtime, stop = entry
        stop.set()
        runtime.join(timeout=timeout)
        return {"existed": True}

    def _promote_pod(self, body: dict) -> dict:
        """Re-key a holding standby under the primary pod name and wake it
        into the data plane (mirrors ``KubeletController.adopt_standby`` +
        ``signal_promote`` for the in-process path)."""
        with self._plock:
            entry = self._pods.pop(body["standby"], None)
            if entry is None or body["primary"] in self._pods:
                if entry is not None:  # primary already live: put it back
                    self._pods[body["standby"]] = entry
                return {"promoted": False}
            self._pods[body["primary"]] = entry
        runtime, _ = entry
        runtime.promote(body.get("launchCount", 0))
        return {"promoted": True}

    def _on_runtime_exit(self, runtime) -> None:
        pod_name = (runtime.pod_name_override
                    or crds.pod_name(runtime.job, runtime.pe_id))
        with self._plock:
            self._pods.pop(pod_name, None)
        self.channel.cast("pod_exit", {
            "pod": pod_name, "crashed": runtime.crashed,
            "drainStats": runtime.drain_stats,
            "stopped": runtime.stop_event.is_set()})

    def _stop_all(self, timeout: float = 2.0) -> None:
        with self._plock:
            entries = list(self._pods.items())
            self._pods.clear()
        for _, (runtime, stop) in entries:
            stop.set()
        for _, (runtime, _) in entries:
            runtime.join(timeout=timeout)


def worker_main() -> None:
    """Entry point of the spawned worker process (see
    ``HostBridge.ensure_worker``); parent address + node name arrive via
    environment so the command line stays a plain importable ``-c``."""
    parent = os.environ["REPRO_WORKER_PARENT"]
    node = os.environ["REPRO_WORKER_NODE"]
    host, _, port = parent.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=10.0)
    WorkerHost(sock, node).run()
