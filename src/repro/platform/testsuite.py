"""Test-harness operator (paper §6.6): the TestSuite CRD and its actors.

A TestSuite CRD holds five lists — pending / running / passed / failed /
aborted — plus run parameters (concurrency, failure threshold).  The
TestSuite controller admits up to ``concurrency`` tests from pending to
running and creates a pod for each; when a test pod finishes, the pod
controller reports the outcome through the TestSuite *coordinator*, which
serially recomputes the lists, admits the next pending test, and updates
the CRD.  All important state lives in the CRD: the harness is resilient
to restarts, discoverable with standard tooling, and blind to what the
test runners actually do (it only manipulates pods and their phases).

Test payloads here are platform scenarios (the paper's tests are SPL
applications): each test is a named scenario function executed inside the
pod's runtime thread; probes assert on resource states.
"""

from __future__ import annotations

import threading
import time
import traceback

from ..core import Controller, Coordinator, EventType, Resource, ResourceStore
from . import crds

TEST_POD = "TestPod"  # test-runner pods get their own kind to keep the
#                       application pod controllers out of their life cycle


def make_test_suite(name: str, tests: list, concurrency: int = 2,
                    failure_threshold: int = 0,
                    namespace: str = "default") -> Resource:
    return Resource(
        kind=crds.TEST_SUITE, name=name, namespace=namespace,
        spec={"tests": list(tests), "concurrency": concurrency,
              "failureThreshold": failure_threshold},
        status={"pending": list(tests), "running": [], "passed": [],
                "failed": [], "aborted": [], "state": "Running"},
    )


class TestRunnerKubelet:
    """Executes test-runner pods (threads running scenario callables)."""

    def __init__(self, registry: dict):
        self.registry = registry
        self._threads: dict = {}

    def start(self, pod: Resource, report) -> None:
        test = pod.spec["test"]
        fn = self.registry.get(test)

        def run():
            try:
                if fn is None:
                    raise KeyError(f"unknown test {test!r}")
                fn()
                report(pod.name, test, "passed")
            except Exception:  # noqa: BLE001 — test failure
                traceback.print_exc()
                report(pod.name, test, "failed")

        t = threading.Thread(target=run, name=f"test-{test}", daemon=True)
        self._threads[pod.name] = t
        t.start()


class TestSuiteController(Controller):
    """Admits pending tests up to the concurrency limit; creates test pods."""

    def __init__(self, store: ResourceStore, namespace, coord: Coordinator,
                 kubelet: TestRunnerKubelet, trace=None):
        super().__init__(store, crds.TEST_SUITE, namespace,
                         "testsuite-controller", trace)
        self.coord = coord
        self.kubelet = kubelet

    def on_addition(self, suite: Resource) -> None:
        self._admit(suite)

    def on_modification(self, old, new: Resource) -> None:
        self._admit(new)

    def _admit(self, suite: Resource) -> None:
        if suite.status.get("state") != "Running":
            return
        conc = suite.spec.get("concurrency", 2)
        running = suite.status.get("running", [])
        pending = suite.status.get("pending", [])
        to_start = []

        def admit(res: Resource) -> None:
            while (len(res.status["running"]) < conc and res.status["pending"]):
                test = res.status["pending"].pop(0)
                res.status["running"].append(test)
                to_start.append(test)

        updated = self.coord.submit(suite.name, admit, requester=self.name)
        if updated is None:
            return
        for test in to_start:
            pod = Resource(
                kind=TEST_POD, name=f"{suite.name}-{test}",
                namespace=self.namespace or "default",
                spec={"suite": suite.name, "test": test},
                status={"phase": "Running"},
            )
            try:
                self.store.create(pod)
            except Exception:
                continue
            self.kubelet.start(pod, self._report)

    def _report(self, pod_name: str, test: str, outcome: str) -> None:
        pod = self.store.try_get(TEST_POD, pod_name, self.namespace or "default")
        if pod is None:
            return
        suite_name = pod.spec["suite"]

        # the paper's TestSuite *coordinator* recomputes the lists serially
        def finish(res: Resource) -> None:
            if test in res.status.get("running", []):
                res.status["running"].remove(test)
            res.status.setdefault(outcome, []).append(test)
            threshold = res.spec.get("failureThreshold", 0)
            failures = len(res.status.get("failed", [])) + len(
                res.status.get("aborted", []))
            if threshold and failures >= threshold:
                res.status["aborted"] = (res.status.get("aborted", []) +
                                         res.status.get("pending", []))
                res.status["pending"] = []
                res.status["state"] = "Aborted"
            elif not res.status.get("pending") and not res.status.get("running"):
                res.status["state"] = "Completed"

        self.coord.submit(suite_name, finish, requester="testsuite-coordinator")
        self.store.try_delete(TEST_POD, pod_name, self.namespace or "default")
        # admission of the next pending test happens via the MODIFIED event


class TestHarness:
    """Standalone harness operator: give it scenarios, submit a TestSuite.

    Runs its own store + runtime so it can drive scenarios against any
    system under test (including full Platform instances the scenarios
    construct internally) — the harness is blind to runner content.
    """

    __test__ = False  # platform component, not a pytest class

    def __init__(self, registry: dict, store: ResourceStore | None = None):
        from ..core import Runtime

        self.store = store or ResourceStore()
        self.registry = registry
        self.coord = Coordinator(self.store, crds.TEST_SUITE)
        self.kubelet = TestRunnerKubelet(registry)
        self.controller = TestSuiteController(self.store, None, self.coord,
                                              self.kubelet)
        self.runtime = Runtime(self.store, threaded=True)
        self.runtime.register(self.controller)

    def run_suite(self, name: str, tests: list, concurrency: int = 2,
                  failure_threshold: int = 0, timeout: float = 300.0) -> dict:
        from ..core import wait_for

        self.store.create(make_test_suite(name, tests, concurrency,
                                          failure_threshold))
        wait_for(lambda: self.store.get(crds.TEST_SUITE, name).status["state"]
                 != "Running", timeout)
        return dict(self.store.get(crds.TEST_SUITE, name).status)

    def shutdown(self) -> None:
        self.runtime.stop()
