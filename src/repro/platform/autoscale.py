"""Elastic autoscaling: ScalingPolicy CRD + the AutoscaleConductor.

Closes the loop the paper's Fig. 9 evaluation measures by hand: PE load
(published by the metrics plane as ``Metrics`` resources) feeds a conductor
that edits ``ParallelRegion`` widths — the same resource a human would
``kubectl edit`` — so the whole §6.3 generation-change causal chain fires
unchanged:

  Metrics MODIFIED -> AutoscaleConductor decides a new width
    -> ParallelRegion coordinator applies the spec edit
    -> ParallelRegionController submits widths to the Job coordinator
    -> Job generation++ -> JobController re-plans -> ConfigMaps rewritten
    -> PodConductor restarts only the PEs whose metadata changed.

The conductor owns no resources and keeps no essential state: policies and
cooldown stamps live in ScalingPolicy CRDs, current widths in ParallelRegion
CRDs, load in Metrics CRDs — a restart recomputes everything by replay.

Scale-down is *graceful*: a width decrease sends the retiring channels
through the drain phase (PE status ``Draining`` -> fabric drain-only ->
runtime pulls its input dry / hands off -> pod deleted), so elasticity
decisions do not cost in-flight tuples.  Two gates keep the conductor from
fighting that machinery:

- the existing health gate (restart churn must not read as low load), and
- a drain gate: while any pod of the job is still draining, no further
  scale decision is taken for it — a second generation change mid-drain
  would re-plan under the drainers and double the churn the drain exists
  to absorb.
"""

from __future__ import annotations

import math
import threading
import time

from ..core import Conductor, Event, EventType, condition_is, get_condition
from . import crds
from .api import ApiClient, ensure_api


def decide_width(current: int, region_agg: dict | None, spec: dict) -> int:
    """Pure scaling decision: region aggregate + policy spec -> wanted width.

    ``backpressure`` mode steps the width by ``step`` when mean queue fill
    crosses the up/down thresholds; ``throughput`` mode sizes the region
    directly from rate / targetPerChannel.  Result is clamped to
    [minWidth, maxWidth].  Cooldown is the caller's concern (it needs a
    clock; this function stays pure).
    """
    lo = spec.get("minWidth", 1)
    hi = spec.get("maxWidth", max(current, lo))
    want = current
    if region_agg:
        if spec.get("metric", "backpressure") == "throughput":
            target = spec.get("targetPerChannel") or 0
            if target > 0:
                want = math.ceil(region_agg.get("throughput", 0.0) / target)
        else:
            bp = region_agg.get("backpressure", 0.0)
            step = spec.get("step", 1)
            if bp > spec.get("scaleUpAt", 0.5):
                want = current + step
            elif bp < spec.get("scaleDownAt", 0.05):
                want = current - step
    return max(lo, min(hi, want))


class AutoscaleConductor(Conductor):
    """Watches Metrics + ScalingPolicy (+ ParallelRegion) events and drives
    region widths toward what the policies ask for."""

    kinds = (crds.METRICS, crds.SCALING_POLICY, crds.PARALLEL_REGION)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 clock=time.monotonic):
        super().__init__(store, "autoscale-conductor", trace)
        self.namespace = namespace
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.clock = clock
        # events arrive from several controller threads; decisions must be
        # serialized or two evaluates could double-step inside one cooldown
        self._lock = threading.Lock()

    def on_event(self, event: Event) -> None:
        if event.type == EventType.DELETED:
            return
        job = event.resource.spec.get("job")
        if job:
            self.evaluate(job)

    # ------------------------------------------------------------ decisions

    def evaluate(self, job: str, now: float | None = None) -> list:
        """Evaluate every policy of ``job``; returns (region, old, new) for
        each width change submitted."""
        with self._lock:
            return self._evaluate(job, now)

    def _evaluate(self, job: str, now: float | None) -> list:
        now = self.clock() if now is None else now
        if self._draining(job):
            # let the in-flight drain finish before the next generation
            # change; the metrics burst that follows re-triggers evaluation
            return []
        metrics = self.store.try_get(crds.METRICS, crds.metrics_name(job),
                                     self.namespace)
        changes = []
        for pol in self.store.list(crds.SCALING_POLICY, self.namespace,
                                   crds.job_labels(job)):
            region = pol.spec["region"]
            pr = self.store.try_get(crds.PARALLEL_REGION,
                                    crds.pr_name(job, region), self.namespace)
            if pr is None:
                continue
            current = pr.spec.get("width", 1)
            agg = (metrics.status.get("regions", {}).get(region)
                   if metrics is not None else None)
            want = decide_width(current, agg, pol.spec)
            if want == current:
                continue
            if want < current and self._unhealthy(job):
                # restart churn (e.g. from a previous width change) drains
                # queues while PEs are down; that transient low-backpressure
                # reading must not trigger a spurious scale-down
                continue
            cooldown = pol.spec.get("cooldown", 0.0)
            if cooldown and now - pol.status.get("lastScaleAt", 0.0) < cooldown:
                continue
            self._scale(job, region, pol, current, want, now)
            changes.append((region, current, want))
        return changes

    def _draining(self, job: str) -> bool:
        """True while a previous scale-down's drain phase is still running
        (a pod carries the ``streams/drain`` finalizer — or a drain request
        — without a drained report yet)."""
        for pod in self.store.list(crds.POD, self.namespace,
                                   crds.job_labels(job)):
            mid_drain = (crds.DRAIN_FINALIZER in pod.finalizers
                         or pod.status.get("draining"))
            if mid_drain and not pod.status.get("drained"):
                return True
        return False

    def _unhealthy(self, job: str) -> bool:
        """True only when the job conductor has *observed* lost health (the
        ``FullHealth`` condition standing at "False"); no condition means no
        cluster is attached (deterministic mode) and health gating does not
        apply."""
        res = self.store.try_get(crds.JOB, job, self.namespace)
        if res is None:
            return False
        if get_condition(res, crds.COND_FULL_HEALTH) is not None:
            return condition_is(res, crds.COND_FULL_HEALTH, "False")
        return res.status.get("fullHealth") is False  # pre-condition writers

    def _scale(self, job: str, region: str, pol, current: int, want: int,
               now: float) -> None:
        # stamp the cooldown FIRST: if the width edit lands but this actor
        # dies, replay re-evaluates against the already-changed width (no
        # double scale); the reverse order could scale twice on restart.
        self.api.scaling_policies.patch_status(
            pol.name, {"lastScaleAt": now, "lastWidth": want},
            requester=self.name)
        # -> ParallelRegionController -> Job (the §6.3 chain)
        self.api.parallel_regions.patch(crds.pr_name(job, region),
                                        {"width": want}, requester=self.name)
        self._record("scale",
                     (crds.PARALLEL_REGION, self.namespace,
                      crds.pr_name(job, region)),
                     f"{current}->{want}")
