"""Elastic autoscaling: ScalingPolicy CRD + the AutoscaleConductor.

Closes the loop the paper's Fig. 9 evaluation measures by hand: PE load
(published by the metrics plane as ``Metrics`` resources) feeds a conductor
that edits ``ParallelRegion`` widths — the same resource a human would
``kubectl edit`` — so the whole §6.3 generation-change causal chain fires
unchanged:

  Metrics MODIFIED -> AutoscaleConductor decides a new width
    -> ParallelRegion coordinator applies the spec edit
    -> ParallelRegionController submits widths to the Job coordinator
    -> Job generation++ -> JobController re-plans -> ConfigMaps rewritten
    -> PodConductor restarts only the PEs whose metadata changed.

The conductor owns no resources and keeps no essential state: policies and
cooldown stamps live in ScalingPolicy CRDs, current widths in ParallelRegion
CRDs, load in Metrics CRDs — a restart recomputes everything by replay.

Scale-down is *graceful*: a width decrease sends the retiring channels
through the drain phase (PE status ``Draining`` -> fabric drain-only ->
runtime pulls its input dry / hands off -> pod deleted), so elasticity
decisions do not cost in-flight tuples.  Two gates keep the conductor from
fighting that machinery:

- the existing health gate (restart churn must not read as low load),
- a drain gate: while any pod of the job is still draining, no further
  scale decision is taken for it — a second generation change mid-drain
  would re-plan under the drainers and double the churn the drain exists
  to absorb,
- a rebalance gate: while the rebalance conductor is migrating one of the
  job's PEs off a hot node, decisions hold (and vice versa — the rebalance
  conductor holds while a drain is in flight), and
- a pressure gate: a scale-UP is held while the node pressure plane
  reports every node oversubscribed — widening then would amplify a hot
  node instead of spreading onto a cold one (paper §8's oversubscription
  complaint, closed from the policy side).

Policy variants: ``backpressure`` (threshold+step), ``throughput`` (direct
sizing), and ``pid`` — target tracking with a PID law on a region signal
(queue fill or serving slot occupancy), anti-windup by conditional
integration, and a hysteresis deadband (see ``decide_width_pid``).
"""

from __future__ import annotations

import math
import threading
import time

from ..core import Conductor, Event, EventType, condition_is, get_condition
from . import crds
from .api import ApiClient, ensure_api
from .scheduler import job_mid_drain


def decide_width(current: int, region_agg: dict | None, spec: dict) -> int:
    """Pure scaling decision: region aggregate + policy spec -> wanted width.

    ``backpressure`` mode steps the width by ``step`` when mean queue fill
    crosses the up/down thresholds; ``throughput`` mode sizes the region
    directly from rate / targetPerChannel.  Result is clamped to
    [minWidth, maxWidth].  Cooldown is the caller's concern (it needs a
    clock; this function stays pure).
    """
    lo = spec.get("minWidth", 1)
    hi = spec.get("maxWidth", max(current, lo))
    want = current
    if region_agg:
        if spec.get("metric", "backpressure") == "throughput":
            target = spec.get("targetPerChannel") or 0
            if target > 0:
                want = math.ceil(region_agg.get("throughput", 0.0) / target)
        else:
            bp = region_agg.get("backpressure", 0.0)
            step = spec.get("step", 1)
            if bp > spec.get("scaleUpAt", 0.5):
                want = current + step
            elif bp < spec.get("scaleDownAt", 0.05):
                want = current - step
    return max(lo, min(hi, want))


def decide_width_pid(current: int, value: float | None, spec: dict,
                     state: dict | None, now: float) -> tuple:
    """Target-tracking PID decision (``metric: "pid"``): drive the region
    signal named by ``spec["signal"]`` toward ``spec["setpoint"]``.

    Pure function of (current width, signal value, policy spec, controller
    state, clock): returns ``(wanted width, new state)`` where state is
    ``{"error", "integral", "at"}``.

    - **Hysteresis window**: inside the ±``hysteresis`` deadband around the
      setpoint nothing moves and the integral stops accumulating — the
      limit-cycle killer a bare threshold policy lacks.
    - **Anti-windup**: the integral is accumulated *conditionally* — frozen
      whenever the raw (unclamped) output is already saturated past
      minWidth/maxWidth in the error's direction — and clamped to
      ±``integralClamp``, so a long saturation episode cannot bank error
      that later overshoots the other way.
    - The derivative term uses the error delta over the *actual* elapsed
      time (``dt`` capped at 10 s so a conductor pause does not explode it).
    """
    lo = spec.get("minWidth", 1)
    hi = spec.get("maxWidth", max(current, lo))
    state = dict(state or {})
    if value is None:
        return max(lo, min(hi, current)), state
    setpoint = spec.get("setpoint", 0.5)
    err = value - setpoint
    last_at = state.get("at")
    dt = min(now - last_at, 10.0) if last_at is not None else 0.0
    dt = max(dt, 0.0)
    integral = state.get("integral", 0.0)
    if abs(err) <= spec.get("hysteresis", 0.1):
        # deadband: on target — hold width, decay nothing, stamp the clock
        return max(lo, min(hi, current)), \
            {"error": err, "integral": integral, "at": now}
    kp = spec.get("kp", 4.0)
    ki = spec.get("ki", 0.0)
    kd = spec.get("kd", 0.0)
    deriv = ((err - state.get("error", err)) / dt) if dt > 0 else 0.0
    raw = current + kp * err + ki * (integral + err * dt) + kd * deriv
    saturating = (raw > hi and err > 0) or (raw < lo and err < 0)
    if dt > 0 and not saturating:  # conditional integration (anti-windup)
        clamp = abs(spec.get("integralClamp", 8.0))
        integral = max(-clamp, min(clamp, integral + err * dt))
    want = int(round(current + kp * err + ki * integral + kd * deriv))
    return max(lo, min(hi, want)), {"error": err, "integral": integral,
                                    "at": now}


class AutoscaleConductor(Conductor):
    """Watches Metrics + ScalingPolicy (+ ParallelRegion) events and drives
    region widths toward what the policies ask for."""

    kinds = (crds.METRICS, crds.SCALING_POLICY, crds.PARALLEL_REGION)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 clock=time.monotonic):
        super().__init__(store, "autoscale-conductor", trace)
        self.namespace = namespace
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.clock = clock
        # events arrive from several controller threads; decisions must be
        # serialized or two evaluates could double-step inside one cooldown
        self._lock = threading.Lock()
        # PID controller state per policy, persisted to policy status only
        # on scale actions (persisting every evaluation would turn each
        # Metrics event into a policy event into another evaluation); a
        # conductor restart between actions simply re-accumulates
        self._pid: dict = {}

    def on_event(self, event: Event) -> None:
        if event.type == EventType.DELETED:
            return
        job = event.resource.spec.get("job")
        if job:
            self.evaluate(job)

    # ------------------------------------------------------------ decisions

    def evaluate(self, job: str, now: float | None = None) -> list:
        """Evaluate every policy of ``job``; returns (region, old, new) for
        each width change submitted."""
        with self._lock:
            return self._evaluate(job, now)

    def _evaluate(self, job: str, now: float | None) -> list:
        now = self.clock() if now is None else now
        if self._draining(job):
            # let the in-flight drain finish before the next generation
            # change; the metrics burst that follows re-triggers evaluation
            return []
        if self._rebalancing(job):
            # a hot-node migration is moving a PE of this job: a generation
            # change now would re-plan under the moving pod and double the
            # churn (the mirror of the rebalance conductor's drain gate)
            return []
        metrics = self.store.try_get(crds.METRICS, crds.metrics_name(job),
                                     self.namespace)
        changes = []
        for pol in self.store.list(crds.SCALING_POLICY, self.namespace,
                                   crds.job_labels(job)):
            region = pol.spec["region"]
            pr = self.store.try_get(crds.PARALLEL_REGION,
                                    crds.pr_name(job, region), self.namespace)
            if pr is None:
                continue
            current = pr.spec.get("width", 1)
            agg = (metrics.status.get("regions", {}).get(region)
                   if metrics is not None else None)
            new_state = state = None
            if pol.spec.get("metric") == "pid":
                value = (agg or {}).get(pol.spec.get("signal", "backpressure"))
                state = self._pid.get(pol.name, pol.status.get("pid"))
                want, new_state = decide_width_pid(current, value, pol.spec,
                                                   state, now)
            else:
                want = decide_width(current, agg, pol.spec)
            # An evaluation discarded by the health / pressure / cooldown
            # gates must not bank integral — that would be windup through a
            # gate the saturation check cannot see, overshooting the
            # setpoint the moment the gate releases.  Gated paths commit
            # the clock and error but FREEZE the integral at its prior
            # value (conditional integration, extended to the gates).
            def hold_state() -> None:
                if new_state is not None:
                    self._pid[pol.name] = {
                        **new_state,
                        "integral": (state or {}).get("integral", 0.0)}

            if want == current:
                if new_state is not None:
                    self._pid[pol.name] = new_state
                continue
            if want < current and self._unhealthy(job):
                # restart churn (e.g. from a previous width change) drains
                # queues while PEs are down; that transient low-backpressure
                # reading must not trigger a spurious scale-down
                hold_state()
                continue
            if want > current and self._no_cold_capacity():
                # every node is already oversubscribed: widening would only
                # amplify a hot node — hold until the pressure plane shows
                # cold capacity (or the rebalance conductor frees some)
                self._record("hold", pol.key, "no-cold-capacity")
                hold_state()
                continue
            cooldown = pol.spec.get("cooldown", 0.0)
            if cooldown and now - pol.status.get("lastScaleAt", 0.0) < cooldown:
                hold_state()
                continue
            if new_state is not None:
                self._pid[pol.name] = new_state
            self._scale(job, region, pol, current, want, now)
            changes.append((region, current, want))
        return changes

    def _draining(self, job: str) -> bool:
        """True while a previous scale-down's drain phase is still running
        (a pod carries the ``streams/drain`` finalizer — or a drain request
        — without a drained report yet)."""
        return job_mid_drain(self.store, self.namespace, job)

    def _rebalancing(self, job: str) -> bool:
        """True while the rebalance conductor is migrating a PE of ``job``
        off a hot node (its ``Rebalancing`` condition stands until the
        replacement pod reports Running+connected)."""
        return any(condition_is(pe, crds.COND_REBALANCING, "True")
                   for pe in self.store.list(crds.PE, self.namespace,
                                             crds.job_labels(job)))

    def _no_cold_capacity(self) -> bool:
        """True when the pressure plane reports EVERY node oversubscribed
        (``Pressure`` condition True).  No nodes / no conditions (bare
        deterministic stores) means no pressure plane — gate inactive."""
        nodes = self.store.list(kind=crds.NODE)
        if not nodes:
            return False
        seen = False
        for node in nodes:
            cond = get_condition(node, crds.COND_PRESSURE)
            if cond is None:
                return False  # unmonitored node: assume schedulable capacity
            seen = True
            if cond.get("status") != "True":
                return False
        return seen

    def _unhealthy(self, job: str) -> bool:
        """True only when the job conductor has *observed* lost health (the
        ``FullHealth`` condition standing at "False"); no condition means no
        cluster is attached (deterministic mode) and health gating does not
        apply."""
        res = self.store.try_get(crds.JOB, job, self.namespace)
        if res is None:
            return False
        if get_condition(res, crds.COND_FULL_HEALTH) is not None:
            return condition_is(res, crds.COND_FULL_HEALTH, "False")
        return res.status.get("fullHealth") is False  # pre-condition writers

    def _scale(self, job: str, region: str, pol, current: int, want: int,
               now: float) -> None:
        # stamp the cooldown FIRST: if the width edit lands but this actor
        # dies, replay re-evaluates against the already-changed width (no
        # double scale); the reverse order could scale twice on restart.
        stamp = {"lastScaleAt": now, "lastWidth": want}
        if pol.name in self._pid:
            stamp["pid"] = self._pid[pol.name]  # controller state round-trip
        self.api.scaling_policies.patch_status(
            pol.name, stamp, requester=self.name)
        # -> ParallelRegionController -> Job (the §6.3 chain)
        self.api.parallel_regions.patch(crds.pr_name(job, region),
                                        {"width": want}, requester=self.name)
        self._record("scale",
                     (crds.PARALLEL_REGION, self.namespace,
                      crds.pr_name(job, region)),
                     f"{current}->{want}")
