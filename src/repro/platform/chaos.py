"""Chaos plane: fault injection through the platform's own surfaces.

The paper's §8 war stories (pod churn, stragglers, partitions, flapping
nodes) are pathologies the platform *claims* to absorb.  This module makes
the claim falsifiable: a ``FaultInjection`` CRD states a fault
declaratively, the ``ChaosConductor`` executes it through the SAME typed
API and actors everything else uses (no side doors into the store), and
the recovery is measured by the observability plane that already exists —
every injection opens a ``fault`` root span, every expected recovery rides
the ``recover`` spans the SLO conductor judges, and the error-budget
ledger turns each run into a machine-checkable verdict.

Fault taxonomy (``crds.FAULT_KINDS``):

- ``pod-kill``        kill a healthy PE's runtime; recovery = the restart
                      causal chain (launchCount++ -> recreate -> bind ->
                      start -> connected).
- ``kill-mid-drain``  shrink a parallel region, then kill the retiring pod
                      *while its drain is in flight* — racing the
                      ``streams/drain`` finalizer.  Recovery = the
                      retirement converging anyway (resources reaped,
                      delivery-path holds released).
- ``clock-straggle``  skew one pod's reported heartbeat via the REST
                      facade's straggle window: trips the node pressure
                      plane's ``Straggling`` condition, and — past the
                      job's ``stragglerTimeout`` — the straggler monitor's
                      restart chain.
- ``partition``       cut a PE's fabric reach for a window (the PE stays
                      alive).  The operator *quarantines* it
                      (``Quarantined`` condition: no restart, no straggler
                      verdict) while senders back off and re-buffer;
                      recovery = heal + the pod still healthy, zero loss.
- ``node-flap``       delete a node (taking its hosted pods down) and
                      re-add it; the node controller's scheduler kick
                      revives anything stranded Unschedulable.
- ``standby-loss``    kill a protected PE's warm standby, then kill the
                      primary *inside the re-warm window* — the recovery
                      plane's degraded path: promotion is impossible, the
                      failover conductor falls back to the cold restart
                      chain, and a fresh standby re-warms afterwards.

Determinism: ALL chaos randomness — target draws, race-point jitter —
flows through one ``random.Random(spec.seed)`` per injection; the seed is
echoed in the FaultInjection status and the benchmark report, so any run
replays exactly.

Scenario harness: ``run_scenario`` is the one entry point benchmarks and
tests share — create the record, let the conductor execute it, wait for
the terminal phase, collect the status, delete the record (fault records
are harness artifacts, not durable state).
"""

from __future__ import annotations

import random
import threading
import time

from ..core import Conductor, Event, EventType, set_condition, wait_for
from . import crds
from .api import ensure_api
from .tracing import fault_token, pod_token, span_tracer

#: Terminal FaultInjection phases (the harness waits for either).
TERMINAL_PHASES = ("Recovered", "Failed")


class ChaosConductor(Conductor):
    """Executes ``FaultInjection`` resources against the live platform.

    Reacts to ADDED events only (status writes echo back as MODIFIED and
    must not re-fire); each injection runs on its own daemon thread so the
    control loop stays responsive while an executor sleeps through its
    fault window or waits out a recovery chain.  ``execute`` is idempotent
    (phase-gated), so WAL replays of completed injections are no-ops and
    tests may call it synchronously.
    """

    kinds = (crds.FAULT_INJECTION,)

    def __init__(self, store, namespace, coords=None, trace=None, *, api=None,
                 fabric=None, kubelet=None, rest=None, scheduler=None,
                 straggler=None, clock=time.monotonic):
        super().__init__(store, "chaos-conductor", trace)
        self.namespace = namespace
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.fabric = fabric
        self.kubelet = kubelet
        self.rest = rest
        self.scheduler = scheduler
        self.straggler = straggler
        self.clock = clock
        self.injected = 0
        self._threads: list = []

    # ----------------------------------------------------------------- events

    def on_event(self, event: Event) -> None:
        if event.type != EventType.ADDED:
            return
        t = threading.Thread(target=self.execute, args=(event.resource.name,),
                             name=f"chaos-{event.resource.name}", daemon=True)
        self._threads.append(t)
        t.start()

    def join(self, timeout: float = 30.0) -> None:
        """Wait for every in-flight injection to reach a terminal phase."""
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(deadline - time.monotonic(), 0.0))

    # -------------------------------------------------------------- execution

    def execute(self, name: str) -> dict | None:
        """Run one injection to its terminal phase; returns the outcome."""
        res = self.api.fault_injections.try_get(name)
        if res is None or res.status.get("phase") not in (None, "Pending"):
            return None  # replay / double delivery: already executed
        spec = dict(res.spec)
        fault = spec["fault"]
        # satellite of the chaos plane's determinism contract: this is the
        # ONLY source of chaos randomness, and the seed is already echoed
        # in the record's status by make_fault_injection
        rng = random.Random(int(spec.get("seed", 0)))
        if spec.get("delay"):
            time.sleep(float(spec["delay"]))
        sp = span_tracer(self.trace)
        root = None
        if sp is not None:
            root = sp.attach(fault_token(name),
                             sp.start_span("chaos", "fault", res.key,
                                           fault=fault,
                                           job=spec.get("job") or "-"))
        t0 = self.clock()

        def mark_injected(r) -> None:
            r.status.update(phase="Injected", injectedAt=t0)
            set_condition(r, crds.COND_FAULT_INJECTED, "True", reason=fault)

        self.api.fault_injections.edit(name, mark_injected,
                                       requester=self.name)
        self.injected += 1
        self._record("inject", res.key, fault)
        try:
            outcome = self._EXECUTORS[fault](self, spec, rng, root)
            ok = True
        except Exception as exc:  # noqa: BLE001 — a blown injection is a
            #   Failed verdict on the record, not a dead conductor thread
            outcome = {"error": repr(exc)}
            ok = False
        t1 = self.clock()

        def finish(r) -> None:
            r.status.update(phase="Recovered" if ok else "Failed",
                            recoveredAt=t1, recoverS=round(t1 - t0, 4),
                            outcome=outcome)
            if outcome.get("chosen") is not None:
                r.status["chosen"] = outcome["chosen"]
            set_condition(r, crds.COND_FAULT_RECOVERED,
                          "True" if ok else "False",
                          reason="Healed" if ok else "RecoveryFailed",
                          message=str(outcome.get("error", ""))[:200])

        self.api.fault_injections.edit(name, finish, requester=self.name)
        if sp is not None:
            sp.end_span(sp.detach(fault_token(name)), ok=ok)
        self._record("recovered" if ok else "failed", res.key,
                     f"{t1 - t0:.3f}s")
        return outcome

    # ------------------------------------------------------------- targeting

    def _pick_pe(self, job: str, rng: random.Random, target: dict) -> int:
        """The seeded target draw: an explicit ``target.pe`` wins; otherwise
        a uniform draw over the job's running, non-draining pods (sorted
        before the draw so equal seeds pick equal victims)."""
        if target.get("pe") is not None:
            return int(target["pe"])
        floor = int(target.get("minPe", 0))
        pods = sorted((p for p in self.store.list(crds.POD, self.namespace,
                                                  crds.job_labels(job))
                       if p.status.get("phase") == "Running"
                       and not p.terminating
                       and not p.status.get("draining")
                       and not p.spec.get("standby")
                       and p.spec["peId"] >= floor),
                      key=lambda p: p.spec["peId"])
        if not pods:
            raise RuntimeError(f"job {job!r}: no running pod to target")
        return rng.choice(pods).spec["peId"]

    # --------------------------------------------------------- recovery gates

    def _pod_recovered(self, job: str, pe: int, before_launch: int) -> bool:
        """A *replacement* incarnation is serving: later launch, Running,
        and its runtime reported connected."""
        pod = self.api.pods.try_get(crds.pod_name(job, pe))
        return (pod is not None
                and pod.spec.get("launchCount", 0) > before_launch
                and pod.status.get("phase") == "Running"
                and bool(pod.status.get("connected")))

    def _pod_healthy(self, job: str, pe: int) -> bool:
        pod = self.api.pods.try_get(crds.pod_name(job, pe))
        return (pod is not None and pod.status.get("phase") == "Running"
                and bool(pod.status.get("connected")))

    def _open_recover(self, pod, root, cause: str):
        """Pre-attach the recovery span under the pod token BEFORE injecting,
        parented to the fault root: the kubelet's ``kill_pod`` and the pod
        controller's ``_bump`` both skip their own attach when a context
        already stands, and ``notify_connected`` ends whatever is attached —
        so the platform's own recovery chain closes OUR span, and the SLO
        conductor's ``recover``-span judgement covers injected faults for
        free."""
        sp = span_tracer(self.trace)
        if sp is None or sp.context(pod_token(pod.name)) is not None:
            return None
        return sp.attach(pod_token(pod.name),
                         sp.start_span("chaos", "recover", pod.key,
                                       parent=root, job=pod.spec["job"],
                                       pe=pod.spec["peId"], cause=cause))

    def _abort_recover(self, pod_name: str, rec) -> None:
        """Recovery never came: close + detach the span so it cannot sit
        open forever poisoning every later SLO recovery judgement."""
        sp = span_tracer(self.trace)
        if sp is not None and rec is not None \
                and sp.context(pod_token(pod_name)) is rec:
            sp.end_span(sp.detach(pod_token(pod_name)), aborted=True)

    def _span_ms(self, rec) -> dict:
        if rec is None or rec.t1 is None:
            return {}
        return {"recoverSpanMs": round(rec.duration_ms, 2)}

    # -------------------------------------------------------------- executors

    def _fault_pod_kill(self, spec: dict, rng: random.Random, root) -> dict:
        job = spec["job"]
        pe = self._pick_pe(job, rng, spec.get("target") or {})
        pod_name = crds.pod_name(job, pe)
        pod = self.api.pods.get(pod_name)
        before = pod.spec.get("launchCount", 0)
        rec = self._open_recover(pod, root, "pod-kill")
        try:
            if not self.kubelet.kill_pod(pod_name):
                raise RuntimeError(f"{pod_name}: no running runtime to kill")
            bound = float((spec.get("params") or {}).get("recoveryTimeout",
                                                         30.0))
            if not wait_for(lambda: self._pod_recovered(job, pe, before),
                            bound):
                raise RuntimeError(f"{pod_name}: not recovered in {bound}s")
        except Exception:
            self._abort_recover(pod_name, rec)
            raise
        return {"chosen": {"pe": pe}, **self._span_ms(rec)}

    def _fault_kill_mid_drain(self, spec: dict, rng: random.Random,
                              root) -> dict:
        """Shrink a region by one, then kill the retiring pod *inside* its
        drain window — the injected race against the ``streams/drain``
        finalizer.  Either outcome of the race (kill lands mid-drain, or
        the drain finishes first and the kill whiffs) must converge to the
        same terminal state: the retiring resource set fully reaped."""
        job = spec["job"]
        params = spec.get("params") or {}
        region = params.get("region")
        if region is None:
            prs = sorted(self.api.parallel_regions.list(crds.job_labels(job)),
                         key=lambda r: r.name)
            if not prs:
                raise RuntimeError(f"job {job!r}: no parallel region to shrink")
            region = rng.choice(prs).spec["region"]
        pr_name = crds.pr_name(job, region)
        width = self.api.parallel_regions.get(pr_name).spec["width"]
        if width < 2:
            raise RuntimeError(f"{pr_name}: width {width} cannot scale down")
        self.api.parallel_regions.patch(pr_name, {"width": width - 1},
                                        requester=self.name)
        found: dict = {}

        def drain_began() -> bool:
            for p in self.store.list(crds.POD, self.namespace,
                                     crds.job_labels(job)):
                if p.status.get("draining") and not p.status.get("drained"):
                    found.setdefault("pod", p)
                    return True
            return "pod" in found  # drained so fast we only see the wake

        if not wait_for(drain_began, float(params.get("drainTimeout", 10.0))):
            raise RuntimeError(f"{pr_name}: no drain began after width cut")
        victim = found["pod"]
        pe = victim.spec["peId"]
        # land the kill at a seeded point inside the drain window
        time.sleep(rng.uniform(0.0, float(spec.get("duration", 0.05))))
        killed = self.kubelet.kill_pod(victim.name)
        bound = float(params.get("recoveryTimeout", 30.0))
        reaped = (self.api.pods.wait_deleted(victim.name, timeout=bound)
                  and self.api.pes.wait_deleted(crds.pe_name(job, pe),
                                                timeout=bound))
        if not reaped:
            raise RuntimeError(f"{victim.name}: retirement did not converge")
        return {"chosen": {"pe": pe, "region": region},
                "killedMidDrain": bool(killed)}

    def _fault_clock_straggle(self, spec: dict, rng: random.Random,
                              root) -> dict:
        job = spec["job"]
        pe = self._pick_pe(job, rng, spec.get("target") or {})
        pod_name = crds.pod_name(job, pe)
        pod = self.api.pods.get(pod_name)
        node = pod.spec.get("nodeName")
        params = spec.get("params") or {}
        offset = float(params.get("offset", 8.0))
        duration = float(spec.get("duration", 0.5))
        bound = float(params.get("recoveryTimeout", 30.0))
        job_res = self.api.jobs.try_get(job)
        straggler_timeout = (job_res.spec.get("stragglerTimeout")
                             if job_res is not None else None)
        expect_restart = (straggler_timeout is not None
                          and offset > float(straggler_timeout))
        before = pod.spec.get("launchCount", 0)
        rec = (self._open_recover(pod, root, "clock-straggle")
               if expect_restart else None)
        self.rest.straggle_heartbeat(job, pe, offset, duration)
        try:
            if expect_restart:
                # the straggler monitor marks the pod Failed -> the same
                # restart chain as a crash; recovery = replacement connected.
                # The monitor's scans are explicitly driven (its documented
                # deterministic mode) — and the window is cleared the moment
                # the verdict lands, or the REPLACEMENT pod (same name)
                # would report straggled heartbeats too and be re-killed.
                def tripped() -> bool:
                    if self.straggler is not None:
                        if pod_name in self.straggler.scan():
                            self.rest.clear_straggle(job, pe)
                    return self._pod_recovered(job, pe, before)

                if not wait_for(tripped, bound):
                    raise RuntimeError(f"{pod_name}: straggler restart "
                                       f"did not complete in {bound}s")
                return {"chosen": {"pe": pe}, "restarted": True,
                        **self._span_ms(rec)}
            # below the restart threshold: only the node pressure plane
            # trips — Straggling must rise, then clear once the window
            # closes and a fresh heartbeat lands
            if node is None:
                raise RuntimeError(f"{pod_name}: not bound to a node")
            if not wait_for(lambda: self.api.nodes.condition_is(
                    node, crds.COND_STRAGGLING), duration + bound):
                raise RuntimeError(f"{node}: Straggling never tripped")
            self.rest.clear_straggle(job, pe)
            if not wait_for(lambda: self.api.nodes.condition_is(
                    node, crds.COND_STRAGGLING, "False"), bound):
                raise RuntimeError(f"{node}: Straggling never cleared")
            return {"chosen": {"pe": pe, "node": node}, "restarted": False}
        except Exception:
            self.rest.clear_straggle(job, pe)
            self._abort_recover(pod_name, rec)
            raise

    def _fault_partition(self, spec: dict, rng: random.Random, root) -> dict:
        """Cut a live PE's fabric reach for a window.  The PE is quarantined
        first (restart + straggler verdicts gated, senders route around by
        backing off into their widened partition buffers), the fabric
        partition is healed at the deadline, and the quarantine lift
        re-kicks the launch chain only if the pod really died meanwhile."""
        job = spec["job"]
        pe = self._pick_pe(job, rng, spec.get("target") or {})
        pe_name = crds.pe_name(job, pe)
        pod_name = crds.pod_name(job, pe)
        pod = self.api.pods.get(pod_name)
        duration = float(spec.get("duration", 0.5))
        sp = span_tracer(self.trace)
        # no restart is expected, so notify_connected will never close this
        # span — it is NOT attached under the pod token; the conductor ends
        # it itself at heal (the SLO plane still judges it by job attr)
        rec = (sp.start_span("chaos", "recover", pod.key, parent=root,
                             job=job, pe=pe, cause="partition")
               if sp is not None else None)
        # quarantine BEFORE the cut: the operator must already be routing
        # around the PE when senders start hitting Unreachable
        self.api.pes.set_condition(pe_name, crds.COND_QUARANTINED, "True",
                                   reason="Partitioned",
                                   message=f"window={duration}s",
                                   requester=self.name)
        try:
            self.fabric.partition(job, pe, duration)
            time.sleep(duration)
        finally:
            self.fabric.heal(job, pe)  # idempotent with the lazy expiry
            self.api.pes.set_condition(pe_name, crds.COND_QUARANTINED,
                                       "False", reason="Healed",
                                       requester=self.name)
        # quarantine lift: the gated restart chain never ran — if the pod
        # is actually gone, re-kick the launch chain now
        pod_now = self.api.pods.try_get(pod_name)
        if pod_now is None or pod_now.status.get("phase") == "Failed":
            self.api.pes.edit(
                pe_name,
                lambda r: r.status.update(
                    launchCount=r.status.get("launchCount", 0) + 1),
                requester=self.name)
        bound = float((spec.get("params") or {}).get("recoveryTimeout", 30.0))
        healthy = wait_for(lambda: self._pod_healthy(job, pe), bound)
        if sp is not None:
            sp.end_span(rec, healed=healthy)
        if not healthy:
            raise RuntimeError(f"{pod_name}: unhealthy after heal")
        return {"chosen": {"pe": pe}, **self._span_ms(rec)}

    def _fault_node_flap(self, spec: dict, rng: random.Random, root) -> dict:
        """Delete a node (its hosted pods of the target job die with it),
        wait the flap window, re-add it; the node controller's scheduler
        kick revives anything stranded Unschedulable."""
        job = spec.get("job")
        target = spec.get("target") or {}
        selector = crds.job_labels(job) if job else None
        pods = [p for p in self.store.list(crds.POD, self.namespace, selector)
                if p.status.get("phase") == "Running"
                and p.spec.get("nodeName") and not p.terminating]
        node_name = target.get("node")
        if node_name is None:
            hosts = sorted({p.spec["nodeName"] for p in pods})
            if not hosts:
                raise RuntimeError("no node hosting a running pod to flap")
            node_name = rng.choice(hosts)
        node = self.store.try_get(crds.NODE, node_name)
        if node is None:
            raise RuntimeError(f"node {node_name!r} not found")
        cores, labels = node.spec.get("cores", 8), dict(node.labels)
        isolated = bool(node.spec.get("processIsolation"))
        victims = [p for p in pods if p.spec["nodeName"] == node_name]
        before = {p.name: (p.spec["job"], p.spec["peId"],
                           p.spec.get("launchCount", 0)) for p in victims}
        recs = [self._open_recover(p, root, "node-flap") for p in victims]
        self.api.nodes.delete(node_name)
        try:
            for p in victims:
                self.kubelet.kill_pod(p.name)  # the node takes its pods down
            time.sleep(float(spec.get("duration", 0.2)))
        finally:
            self.api.nodes.create(crds.make_node(
                node_name, cores, labels or None,
                process_isolation=isolated))
        bound = float((spec.get("params") or {}).get("recoveryTimeout", 30.0))

        def all_back() -> bool:
            return all(self._pod_recovered(j, p, launch)
                       for j, p, launch in before.values())

        if not wait_for(all_back, bound):
            for p, rec in zip(victims, recs):
                self._abort_recover(p.name, rec)
            raise RuntimeError(f"{node_name}: pods not re-placed in {bound}s")
        return {"chosen": {"node": node_name,
                           "pes": sorted(v[1] for v in before.values())},
                "flapped": len(victims)}

    def _fault_standby_loss(self, spec: dict, rng: random.Random,
                            root) -> dict:
        """Kill a protected PE's warm standby, then the primary back to
        back — the primary dies *inside the re-warm window*, so promotion
        is impossible and the failover conductor must fall back to the cold
        restart chain (degraded path).  Recovery = the replacement
        incarnation connected AND a fresh standby re-warmed behind it."""
        job = spec["job"]
        params = spec.get("params") or {}
        pe = self._pick_pe(job, rng, spec.get("target") or {})
        pe_name = crds.pe_name(job, pe)
        pod_name = crds.pod_name(job, pe)
        standby_name = crds.standby_pod_name(job, pe)
        warm_bound = float(params.get("warmTimeout", 15.0))
        if not self.api.pes.condition_is(pe_name, crds.COND_STANDBY_READY):
            # self-contained: protect the chosen PE if nothing already does
            self.api.standby_policies.apply(
                crds.make_standby_policy(job, pes=[pe],
                                         namespace=self.namespace),
                requester=self.name)
            if not wait_for(lambda: self.api.pes.condition_is(
                    pe_name, crds.COND_STANDBY_READY), warm_bound):
                raise RuntimeError(f"{pe_name}: standby never warmed")
        pod = self.api.pods.get(pod_name)
        before = pod.spec.get("launchCount", 0)
        rec = self._open_recover(pod, root, "standby-loss")
        try:
            if not self.kubelet.kill_pod(standby_name):
                raise RuntimeError(f"{standby_name}: no standby to kill")
            if not self.kubelet.kill_pod(pod_name):
                raise RuntimeError(f"{pod_name}: no running runtime to kill")
            bound = float(params.get("recoveryTimeout", 30.0))
            if not wait_for(lambda: self._pod_recovered(job, pe, before),
                            bound):
                raise RuntimeError(f"{pod_name}: not recovered in {bound}s")
            rewarmed = wait_for(lambda: self.api.pes.condition_is(
                pe_name, crds.COND_STANDBY_READY), warm_bound)
        except Exception:
            self._abort_recover(pod_name, rec)
            raise
        return {"chosen": {"pe": pe}, "degraded": True,
                "reWarmed": bool(rewarmed), **self._span_ms(rec)}

    _EXECUTORS = {
        "pod-kill": _fault_pod_kill,
        "kill-mid-drain": _fault_kill_mid_drain,
        "clock-straggle": _fault_clock_straggle,
        "partition": _fault_partition,
        "node-flap": _fault_node_flap,
        "standby-loss": _fault_standby_loss,
    }


# ------------------------------------------------------------------ harness


def run_scenario(platform, *, fault: str, job: str | None = None,
                 tag: str | None = None, seed: int = 0,
                 target: dict | None = None, delay: float = 0.0,
                 duration: float = 0.5, params: dict | None = None,
                 timeout: float = 60.0) -> dict:
    """One scenario, end to end, through the declarative surface:

    create the ``FaultInjection`` record -> the ChaosConductor executes it
    -> wait for the terminal phase -> collect status -> delete the record
    (it is a harness artifact; leaving it would hold ``wait_terminated``
    open on the job's label set forever).  Returns the record's final
    status plus a ``completed`` flag."""
    name = crds.fault_name(job or "cluster", tag or fault)
    platform.api.fault_injections.create(crds.make_fault_injection(
        name, fault=fault, job=job, target=target, delay=delay,
        duration=duration, seed=seed, params=params,
        namespace=platform.namespace))

    def terminal() -> bool:
        res = platform.api.fault_injections.try_get(name)
        return res is not None and res.status.get("phase") in TERMINAL_PHASES

    completed = wait_for(terminal, timeout)
    res = platform.api.fault_injections.try_get(name)
    status = dict(res.status) if res is not None else {}
    platform.api.fault_injections.delete(name)
    status["name"] = name
    status["fault"] = fault
    status["completed"] = completed and status.get("phase") == "Recovered"
    return status


__all__ = ["ChaosConductor", "run_scenario", "TERMINAL_PHASES"]
