"""Warm-standby failover: the recovery plane's sub-second restart path.

The cold restart chain (PR 2-4) is causally clean but long: pod Failed ->
pod controller bumps ``launchCount`` -> pod conductor creates a Pending pod
-> scheduler decide+bind -> kubelet starts a fresh runtime -> publish ->
``notify_connected``.  Every hop is an event dispatch plus (for region PEs)
a checkpoint reload, so recovery time is dominated by machinery, not by
state.  The paper's platform hides most of this behind its PE manager; this
module reproduces the effect with a *warm standby*:

- A ``StandbyPolicy`` CRD names the PEs of a job to protect.  The
  **FailoverConductor** keeps one shadow pod per protected PE
  (``{job}-standby-{pe}``, ``spec.standby: True``) placed on a *different*
  node by the scheduler's pod anti-affinity plugin: the primary's pod
  carries a per-PE label (``crds.pe_affinity_label``), the standby's
  ``podAntiAffinity`` names it.
- The kubelet hosts the standby as a real ``PERuntime`` in *hold* mode: it
  performs no publishes and writes no REST identity, but periodically
  re-warms its state from the latest committed checkpoint
  (``PERuntime._warm_standby``), so promotion starts from hot state.
- On primary failure (crash / kill / stale heartbeat -> pod ``Failed``),
  the pod controller *skips* its cold bump (the PE carries ``StandbyReady``
  or ``Promoting``) and this conductor promotes instead: re-key the live
  standby handle under the primary pod name (``kubelet.adopt_standby``),
  stamp the PE ``Promoting`` with a single ``launchCount`` bump, swap the
  pod records (the replacement is created *pre-bound* to the standby's
  node so neither scheduler nor kubelet re-enter the chain), and wake the
  runtime into the data plane (``kubelet.signal_promote``).  The fresh
  publish rides the fabric's residual-carryover path — the dead primary's
  undelivered ring preloads into the standby's queues — and
  ``notify_connected`` closes the same ``recover`` span the cold chain
  would have closed, so the SLO plane judges both paths identically.
- The conductor also owns checkpoint hygiene: it runs the
  ``CheckpointStore`` sweep whenever a ConsistentRegion commits (the
  operator stamps a ``.committing`` marker around the CRD write, so the
  sweep can never reap the step a commit is mid-flight on).

Degraded path: if the standby itself died inside the re-warm window (the
``standby-loss`` chaos fault), promotion falls back to the cold chain — the
conductor clears ``StandbyReady`` and performs the launchCount bump the pod
controller skipped, then re-warms a fresh standby once the PE recovers.
"""

from __future__ import annotations

import time

from ..core import (
    Conductor,
    Event,
    EventType,
    Resource,
    condition_is,
    set_condition,
)
from . import crds
from .api import ensure_api
from .tracing import migrate_token, pod_token, span_tracer


class FailoverConductor(Conductor):
    """Keeps warm standbys converged to ``StandbyPolicy`` and promotes one
    on primary failure; sweeps committed checkpoints.  See the module
    docstring for the full promotion walkthrough."""

    kinds = (crds.STANDBY_POLICY, crds.POD, crds.CONSISTENT_REGION)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 kubelet=None, ckpt=None, enabled: bool = True,
                 clock=time.time):
        super().__init__(store, "failover-conductor", trace)
        self.namespace = namespace
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.kubelet = kubelet
        self.ckpt = ckpt
        self.enabled = enabled
        self.clock = clock
        self.promotions = 0
        self.degraded_failovers = 0
        self.sweeps = 0

    # --------------------------------------------------------------- events

    def on_event(self, event: Event) -> None:
        res = event.resource
        if res.kind == crds.CONSISTENT_REGION:
            self._maybe_sweep(event)
            return
        if not self.enabled:
            return
        if res.kind == crds.STANDBY_POLICY:
            if event.type == EventType.DELETED:
                self._teardown_policy(res)
            else:
                self._reconcile_policy(res)
            return
        # pod events
        if res.spec.get("standby"):
            self._on_standby_pod(event)
        else:
            self._on_primary_pod(event)

    # ----------------------------------------------------- checkpoint sweep

    def _maybe_sweep(self, event: Event) -> None:
        """Reap strictly-older uncommitted checkpoint steps once a commit
        lands (satellite: the sweep runs here, not ad hoc in the commit
        path — and ``CheckpointStore.sweep`` itself spares any step carrying
        a ``.committing`` marker)."""
        if self.ckpt is None or event.type == EventType.DELETED:
            return
        cr = event.resource
        committed = cr.status.get("lastCommitted", -1)
        if committed < 0:
            return
        old = getattr(event, "old", None)
        if old is not None and old.status.get("lastCommitted", -1) == committed:
            return  # no new commit in this event
        removed = self.ckpt.sweep(cr.spec["job"], cr.spec["region"], committed)
        if removed:
            self.sweeps += removed
            self._record("sweep", cr.key, f"committed={committed} removed={removed}")

    # -------------------------------------------------------- policy -> pes

    def _policy_for(self, job: str) -> Resource | None:
        return self.api.standby_policies.try_get(crds.standby_policy_name(job))

    def _protected_pes(self, policy: Resource) -> list[int]:
        """PE ids the policy protects: the explicit list, else every
        non-source PE of the job (sources regenerate their stream; standby
        state warming buys them nothing)."""
        explicit = policy.spec.get("pes") or []
        if explicit:
            return sorted(int(p) for p in explicit)
        job = policy.spec["job"]
        out = []
        for pe in self.store.list(crds.PE, self.namespace,
                                  crds.job_labels(job)):
            cm = self.store.try_get(
                crds.CONFIG_MAP, crds.cm_name(job, pe.spec["peId"]),
                self.namespace)
            ops = (cm.spec.get("data", {}).get("operators")
                   if cm is not None else None) or []
            if any(op.get("kind") == "source" for op in ops):
                continue
            out.append(pe.spec["peId"])
        return sorted(out)

    def _reconcile_policy(self, policy: Resource) -> None:
        job = policy.spec["job"]
        for pe_id in self._protected_pes(policy):
            self._ensure_standby(job, pe_id, policy)

    def _teardown_policy(self, policy: Resource) -> None:
        job = policy.spec["job"]
        for pod in self.store.list(crds.POD, self.namespace,
                                   crds.job_labels(job)):
            if not pod.spec.get("standby"):
                continue
            self.api.pods.delete(pod.name)
            self.api.pes.set_condition(
                crds.pe_name(job, pod.spec["peId"]), crds.COND_STANDBY_READY,
                "False", reason="PolicyDeleted", requester=self.name)
        self._record("teardown", policy.key)

    # ----------------------------------------------------- standby ensuring

    def _ensure_standby(self, job: str, pe_id: int,
                        policy: Resource | None = None) -> None:
        """Converge one protected PE to 'a warm standby exists': label the
        primary for anti-affinity, create the shadow pod, and let the
        scheduler place it on a different node."""
        policy = policy or self._policy_for(job)
        if policy is None or policy.terminating:
            return
        if pe_id not in self._protected_pes(policy):
            return  # the policy names its PEs; the rest stay unshadowed
        if self.api.pods.exists(crds.standby_pod_name(job, pe_id)):
            return
        pe = self.api.pes.try_get(crds.pe_name(job, pe_id))
        if pe is None or pe.terminating or \
                pe.status.get("state") == "Draining" or \
                condition_is(pe, crds.COND_PROMOTING):
            return
        primary = self.api.pods.try_get(crds.pod_name(job, pe_id))
        if primary is None or primary.terminating or \
                primary.status.get("phase") != "Running" or \
                not primary.spec.get("nodeName"):
            return  # wait for a placed, running primary to pair against
        label = crds.pe_affinity_label(job, pe_id)
        self._stamp_affinity_label(pe, primary, label)
        base = dict(primary.spec.get("pod_spec") or {})
        base.pop("nodeName", None)  # a host-pinned copy would defeat the pair
        labels = dict(base.get("labels") or {})
        labels.pop(label, None)  # the label marks the *primary* of the pair
        base["labels"] = labels
        anti = list(base.get("podAntiAffinity") or ())
        if label not in anti:
            anti.append(label)
        base["podAntiAffinity"] = anti
        base["avoidNodes"] = [primary.spec["nodeName"]]
        cm = self.store.try_get(crds.CONFIG_MAP, crds.cm_name(job, pe_id),
                                self.namespace)
        generation = cm.spec.get("jobGeneration", 1) if cm is not None else 1
        standby = crds.make_standby_pod(
            job, pe_id,
            {"pod_spec": base,
             "warmInterval": policy.spec.get("warmInterval", 0.5)},
            primary.spec.get("launchCount", 0), generation, self.namespace)
        try:
            self.api.pods.create(standby)
        except Exception:
            return  # lost a race with a concurrent ensure; converged anyway
        self._record("ensure-standby", standby.key,
                     f"avoid={primary.spec['nodeName']}")

    def _stamp_affinity_label(self, pe: Resource, primary: Resource,
                              label: str) -> None:
        """The per-PE label must survive every future incarnation, so it is
        stamped into the PE's podSpec (the pod conductor's template) *and*
        onto the live pod record (the anti-affinity filter reads placed
        pods, which predate the stamp)."""
        def mark_pe(res: Resource) -> None:
            spec = dict(res.spec.get("podSpec") or {})
            labels = dict(spec.get("labels") or {})
            labels[label] = "primary"
            spec["labels"] = labels
            res.spec["podSpec"] = spec

        def mark_pod(res: Resource) -> None:
            spec = dict(res.spec.get("pod_spec") or {})
            labels = dict(spec.get("labels") or {})
            labels[label] = "primary"
            spec["labels"] = labels
            res.spec["pod_spec"] = spec

        if label not in (pe.spec.get("podSpec") or {}).get("labels", {}):
            self.api.pes.edit(pe.name, mark_pe, requester=self.name)
        if label not in (primary.spec.get("pod_spec") or {}).get("labels", {}):
            self.api.pods.edit(primary.name, mark_pod, requester=self.name)

    # ------------------------------------------------------- standby events

    def _on_standby_pod(self, event: Event) -> None:
        pod = event.resource
        job, pe_id = pod.spec["job"], pod.spec["peId"]
        pe_name = crds.pe_name(job, pe_id)
        if event.type == EventType.DELETED or \
                pod.status.get("phase") == "Failed":
            # the re-warm window: the PE is unprotected until a fresh
            # standby comes up (the ``standby-loss`` fault lives here)
            self.api.pes.set_condition(pe_name, crds.COND_STANDBY_READY,
                                       "False", reason="StandbyLost",
                                       requester=self.name)
            if event.type != EventType.DELETED:
                self.api.pods.delete(pod.name)
            else:
                self._ensure_standby(job, pe_id)
            self._record("standby-lost", pod.key)
            return
        if pod.status.get("phase") == "Running" and \
                pod.status.get("warmed") and \
                not condition_is(self.api.pes.try_get(pe_name) or pod,
                                 crds.COND_STANDBY_READY):
            pe = self.api.pes.try_get(pe_name)
            if pe is None or pe.terminating:
                return
            self.api.pes.set_condition(pe_name, crds.COND_STANDBY_READY,
                                       "True", reason="StandbyWarm",
                                       message=pod.spec.get("nodeName", "?"),
                                       requester=self.name)
            entry = {"standbyPod": pod.name,
                     "node": pod.spec.get("nodeName", "?"),
                     "since": self.clock()}

            def note(res: Resource) -> None:
                protected = dict(res.status.get("protected") or {})
                protected[str(pe_id)] = entry
                res.status["protected"] = protected

            self.api.standby_policies.edit(crds.standby_policy_name(job),
                                           note, requester=self.name)
            self._record("standby-ready", pod.key,
                         pod.spec.get("nodeName", "?"))

    # ------------------------------------------------------- primary events

    def _on_primary_pod(self, event: Event) -> None:
        pod = event.resource
        job = pod.spec.get("job")
        pe_id = pod.spec.get("peId")
        if job is None or pe_id is None:
            return
        pe = self.api.pes.try_get(crds.pe_name(job, pe_id))
        if pe is None or pe.terminating:
            return
        failed = (event.type == EventType.DELETED or
                  pod.status.get("phase") == "Failed")
        if failed and pe.status.get("state") != "Draining" and \
                condition_is(pe, crds.COND_STANDBY_READY):
            self._promote(pe, pod)
            return
        if event.type == EventType.DELETED:
            return
        if pod.status.get("phase") == "Running" and \
                pod.status.get("connected"):
            if condition_is(pe, crds.COND_PROMOTING) and \
                    pod.spec.get("launchCount", 0) >= \
                    pe.status.get("launchCount", 0):
                self._complete_promotion(pe, pod)
            elif self._policy_for(job) is not None:
                # healthy primary under a policy: converge its standby
                self._ensure_standby(job, pe_id)

    # ------------------------------------------------------------ promotion

    def _promote(self, pe: Resource, failed_pod: Resource) -> None:
        """The tentpole move: swap the warm standby in under the primary's
        identity.  Handle re-key FIRST (the kubelet's handles-dict guard
        then blocks any concurrent ``_maybe_start`` of the replacement
        record), then one ``Promoting`` + launchCount edit, then the record
        swap, then wake the runtime."""
        job, pe_id = pe.spec["job"], pe.spec["peId"]
        primary_name = crds.pod_name(job, pe_id)
        standby_name = crds.standby_pod_name(job, pe_id)
        node = None
        if self.kubelet is not None:
            node = self.kubelet.adopt_standby(standby_name, primary_name)
        if node is None:
            self._degraded_failover(pe, primary_name, standby_name)
            return
        sp = span_tracer(self.trace)
        if sp is not None and sp.context(pod_token(primary_name)) is None:
            # same span the cold chain's _bump would open: failure detected
            # -> replacement connected; the SLO plane sees one shape
            sp.attach(pod_token(primary_name),
                      sp.start_span(self.name, "recover",
                                    (crds.POD, self.namespace, primary_name),
                                    parent=sp.context(migrate_token(pe.name)),
                                    job=job, pe=pe_id, cause="failover"))
        new_lc = pe.status.get("launchCount", 0) + 1

        def mark(res: Resource) -> None:
            if res.terminating:
                return
            res.status["launchCount"] = new_lc
            set_condition(res, crds.COND_PROMOTING, "True",
                          reason="PrimaryFailed", message=node)
            set_condition(res, crds.COND_STANDBY_READY, "False",
                          reason="Promoting")

        marked = self.api.pes.edit(pe.name, mark, requester=self.name)
        if marked is None or not condition_is(marked, crds.COND_PROMOTING):
            return  # teardown got the PE first
        # Record swap.  The primary record is rebound IN PLACE (never
        # deleted: the kubelet stops handles by pod name on record deletion,
        # which would kill the runtime just adopted under the primary name);
        # the standby record is retired (its handle is already re-keyed, so
        # the kubelet's stop is a no-op).  The rebound record is not Pending,
        # so neither scheduler nor kubelet re-enter the start chain.
        def rebind(res: Resource) -> None:
            res.spec["launchCount"] = new_lc
            res.spec["nodeName"] = node
            res.status["phase"] = "Running"
            res.status["connected"] = False  # the promoted publish resets it

        if self.api.pods.edit(primary_name, rebind,
                              requester=self.name) is None:
            # primary record already reaped (DELETED-triggered promotion):
            # create the replacement pre-bound to the standby's node
            replacement = crds.make_pod(
                job, pe_id, {"pod_spec": dict(pe.spec.get("podSpec") or {})},
                new_lc, failed_pod.spec.get("jobGeneration", 1),
                self.namespace)
            replacement.spec["nodeName"] = node
            replacement.status["phase"] = "Running"
            try:
                self.api.pods.create(replacement)
            except Exception:  # noqa: BLE001 — lost a create race; converged
                pass
        self.api.pods.delete(standby_name)
        ok = self.kubelet.signal_promote(standby_name, primary_name, new_lc)
        self.promotions += 1
        self._record("promote", (crds.POD, self.namespace, primary_name),
                     f"node={node} launch={new_lc} signalled={ok}")

    def _degraded_failover(self, pe: Resource, primary_name: str,
                           standby_name: str) -> None:
        """Standby died inside the re-warm window (or lives on a lost
        worker): fall back to the cold chain the pod controller skipped —
        clear ``StandbyReady`` and perform the bump ourselves."""
        job, pe_id = pe.spec["job"], pe.spec["peId"]
        self.api.pods.delete(standby_name)
        sp = span_tracer(self.trace)
        if sp is not None and sp.context(pod_token(primary_name)) is None:
            sp.attach(pod_token(primary_name),
                      sp.start_span(self.name, "recover",
                                    (crds.POD, self.namespace, primary_name),
                                    parent=sp.context(migrate_token(pe.name)),
                                    job=job, pe=pe_id, cause="degraded"))

        def mark(res: Resource) -> None:
            if res.terminating:
                return
            res.status["launchCount"] = res.status.get("launchCount", 0) + 1
            set_condition(res, crds.COND_STANDBY_READY, "False",
                          reason="StandbyLost")

        self.api.pes.edit(pe.name, mark, requester=self.name)
        self.degraded_failovers += 1
        self._record("degraded-failover",
                     (crds.POD, self.namespace, primary_name))

    def _complete_promotion(self, pe: Resource, pod: Resource) -> None:
        """The promoted runtime reported Running+connected: close out the
        ``Promoting`` epoch and re-warm a fresh standby for the next
        failure."""
        job, pe_id = pe.spec["job"], pe.spec["peId"]
        self.api.pes.set_condition(pe.name, crds.COND_PROMOTING, "False",
                                   reason="PromotionComplete",
                                   requester=self.name)
        policy = self._policy_for(job)
        if policy is not None:
            self.api.standby_policies.patch_status(
                policy.name,
                {"promotions": policy.status.get("promotions", 0) + 1},
                requester=self.name)
        self._record("promotion-complete", pod.key)
        self._ensure_standby(job, pe_id)


__all__ = ["FailoverConductor"]
