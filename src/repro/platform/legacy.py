"""Legacy-platform baseline: the monolithic, synchronous architecture.

The paper's §8 compares cloud-native Streams against legacy Streams; this
module is that baseline, faithful to the legacy traits the paper calls out:

- **synchronous, monolithic submission** (§6.1 "the entire process would not
  return until the job was either scheduled and placed, or failed");
- **store-everything state** (§5.3): the full topology model — every node
  and edge — is written to the ZooKeeper-stand-in, fine-grained, and kept
  for the job's lifetime (vs the cloud-native "store only what you can't
  compute");
- **globally unique PE ids / job-unique port ids** (§6.3), so width changes
  cannot reuse the submission path: remove-then-resubmit of affected PEs,
  with the sequential stop-then-start the paper describes;
- **centralized synchronous scheduling** before submission returns;
- port-label **name resolution through the central store** at PE startup
  (the thundering-herd pattern), with a per-lookup cost knob.

It runs the same PE runtimes over the same fabric, so benchmark differences
isolate *platform architecture*, not data-plane implementation.
"""

from __future__ import annotations

import itertools
import threading
import time

from ..core import Resource, get_condition, set_condition
from . import crds
from .fabric import Fabric
from .pipeline import plan_job
from .runtime import PERuntime


class ZooKeeperSim:
    """Fine-grained synchronous KV store with a per-op latency knob."""

    def __init__(self, op_cost: float = 0.0005):
        self._data: dict = {}
        self._lock = threading.Lock()
        self.op_cost = op_cost
        self.ops = 0

    def put(self, key: str, value) -> None:
        time.sleep(self.op_cost)
        with self._lock:
            self._data[key] = value
            self.ops += 1

    def get(self, key: str, default=None):
        time.sleep(self.op_cost)
        with self._lock:
            self.ops += 1
            return self._data.get(key, default)

    def delete_prefix(self, prefix: str) -> int:
        n = 0
        with self._lock:
            for k in list(self._data):
                if k.startswith(prefix):
                    time.sleep(self.op_cost)
                    del self._data[k]
                    n += 1
                    self.ops += 1
        return n


class _LegacyRest:
    """Minimal REST surface for runtimes under the legacy manager."""

    def __init__(self, manager):
        self.manager = manager
        self.ckpt = manager.ckpt

    def notify_connected(self, job, pe_id):
        self.manager.connected.add((job, pe_id))

    def notify_source_done(self, job, pe_id):
        self.manager.done.add((job, pe_id))

    def report_metrics(self, job, pe_id, metrics):
        self.manager.metrics[(job, pe_id)] = metrics

    def report_sink(self, job, pe_id, seen, maxseq):
        self.manager.sinks[(job, pe_id)] = {"seen": seen, "maxseq": maxseq}

    def notify_checkpoint(self, job, region, pe_id, step):
        self.manager.on_checkpoint(job, region, pe_id, step)

    def get_cr_state(self, job, region):
        return self.manager.cr_state.get((job, region))

    def get_routes(self, job, op_name):
        return []

    def routes_epoch(self):
        return 0  # no subscription broker in the legacy baseline


class LegacyPlatform:
    """Monolithic manager: one object owns scheduling, life cycle, state."""

    def __init__(self, num_nodes: int = 4, cores_per_node: int = 8,
                 zk_op_cost: float = 0.0005, ckpt_root: str | None = None):
        import tempfile

        from ..ckpt import CheckpointStore

        self.zk = ZooKeeperSim(zk_op_cost)
        self.fabric = Fabric()
        self.ckpt = CheckpointStore(ckpt_root or tempfile.mkdtemp(prefix="legacy-ckpt-"))
        self.nodes = {f"node{i}": cores_per_node for i in range(num_nodes)}
        self.placement: dict = {}  # (job, pe) -> node
        self.pes: dict = {}  # (job, pe_id) -> (runtime, stop_event, meta)
        self.plans: dict = {}
        self.connected: set = set()
        self.done: set = set()
        self.metrics: dict = {}
        self.sinks: dict = {}
        self.cr_state: dict = {}
        self._cr_pending: dict = {}
        self._global_pe_ids = itertools.count(1)  # instance-global (legacy!)
        self._lock = threading.Lock()
        self.rest = _LegacyRest(self)
        # condition parity with the cloud-native API: the monolith reports
        # the same Submitted / FullHealth condition vocabulary (held in a
        # detached Resource per job — there is no store to put it in)
        self._job_status: dict = {}  # job -> Resource (conditions carrier)

    # ------------------------------------------------------------- submit

    def submit(self, job: str, spec: dict, widths: dict | None = None) -> None:
        """Synchronous + monolithic: returns only once everything is stored,
        scheduled, and started."""
        plan = plan_job(job, spec, widths)
        self.plans[job] = plan
        # store-everything: every operator, edge and port goes to ZooKeeper
        for pe in plan.pes:
            gid = next(self._global_pe_ids)
            self.zk.put(f"/jobs/{job}/pes/{pe.pe_id}/gid", gid)
            for op in pe.operators:
                self.zk.put(f"/jobs/{job}/ops/{op.id}", {
                    "name": op.name, "kind": op.kind, "pe": pe.pe_id})
            for port in pe.input_ports:
                self.zk.put(f"/jobs/{job}/pes/{pe.pe_id}/in/{port['portId']}",
                            port)
            for port in pe.output_ports:
                self.zk.put(f"/jobs/{job}/pes/{pe.pe_id}/out/{port['portId']}",
                            port)
        for a, b in plan.logical.edges:
            self.zk.put(f"/jobs/{job}/edges/{a}->{b}", 1)
        # centralized synchronous scheduling (reject if impossible)
        loads = {n: 0 for n in self.nodes}
        for pe in plan.pes:
            node = min(loads, key=lambda n: loads[n] / self.nodes[n])
            loads[node] += 1
            self.placement[(job, pe.pe_id)] = node
            self.zk.put(f"/jobs/{job}/placement/{pe.pe_id}", node)
        if plan.consistent_region:
            region = plan.consistent_region.get("name", "region")
            self.cr_state[(job, region)] = {"state": "Processing",
                                            "lastCommitted": -1}
        # start every PE synchronously, in order
        for pe in plan.pes:
            self._start_pe(job, pe, plan)
        # synchronous submit: by the time it returns, the job IS submitted
        carrier = self._job_status.setdefault(
            job, Resource(kind="Job", name=job))
        set_condition(carrier, crds.COND_SUBMITTED, "True",
                      reason="SynchronousSubmit")

    def _start_pe(self, job: str, pe, plan) -> None:
        # port-label resolution through the central store (thundering herd)
        for port in pe.output_ports:
            for peer_pe, peer_port in port["to"]:
                self.zk.get(f"/jobs/{job}/pes/{peer_pe}/in/{peer_port}")
        meta = {**pe.graph_metadata, "widths": plan.widths,
                "consistentRegion": plan.consistent_region}
        stop = threading.Event()
        rt = PERuntime(job=job, pe_id=pe.pe_id, metadata=meta,
                       fabric=self.fabric, rest=self.rest, launch_count=1,
                       stop_event=stop, on_exit=self._on_exit)
        self.pes[(job, pe.pe_id)] = (rt, stop, pe)
        rt.start()

    def _on_exit(self, runtime: PERuntime) -> None:
        key = (runtime.job, runtime.pe_id)
        entry = self.pes.get(key)
        if entry is None:
            return
        rt, stop, pe = entry
        if runtime.crashed and not stop.is_set():
            # legacy restart: same host, synchronous, CR rollback
            with self._lock:
                plan = self.plans.get(runtime.job)
                if plan is None:
                    return
                if plan.consistent_region:
                    region = plan.consistent_region.get("name", "region")
                    self.fabric.abort_collectives(runtime.job)
                self._start_pe(runtime.job, pe, plan)

    # -------------------------------------------------------------- waits

    def full_health(self, job: str) -> bool:
        plan = self.plans[job]
        alive = {(job, pe.pe_id) in self.connected or
                 (job, pe.pe_id) in self.done for pe in plan.pes}
        full = all(alive)
        carrier = self._job_status.get(job)
        if carrier is not None:
            set_condition(carrier, crds.COND_FULL_HEALTH,
                          "True" if full else "False")
        return full

    def job_condition(self, job: str, cond_type: str):
        """The cloud-native condition vocabulary over the monolith's state
        (API parity for tests/benchmarks comparing the two platforms)."""
        carrier = self._job_status.get(job)
        return get_condition(carrier, cond_type) if carrier else None

    def on_checkpoint(self, job: str, region: str, pe_id: int, step: int) -> None:
        plan = self.plans.get(job)
        if plan is None:
            return
        members = [pe.pe_id for pe in plan.pes
                   if any(o.in_region_cr and o.kind in ("source", "trainer")
                          for o in pe.operators)]
        with self._lock:
            got = self._cr_pending.setdefault((job, region, step), set())
            got.add(pe_id)
            if set(members).issubset(got):
                # legacy: JCP state goes to ZooKeeper too
                self.zk.put(f"/jobs/{job}/cr/{region}/committed", step)
                self.cr_state[(job, region)] = {"state": "Processing",
                                                "lastCommitted": step}

    # ------------------------------------------------------- width change

    def change_width(self, job: str, region: str, width: int,
                     drain: bool = False) -> None:
        """Legacy semantics: sequential stop-affected, then start-new.

        PE ids are instance-global, so changed PEs get NEW ids; the whole
        affected subgraph stops before anything restarts (paper §6.3/§8).
        By default removed PEs drop their in-flight input — the baseline
        the cloud-native drain phase is measured against.  ``drain=True``
        is the manager-in-the-loop variant: the monolith synchronously
        drives the same runtime drain state machine (pull dry -> handoff to
        the surviving sibling) before stopping, showing the mechanism is
        platform-independent even if the legacy manager must block on it.
        """
        plan = self.plans[job]
        new_plan = plan_job(job, {**_spec_with(plan), "fusion": "one-per-op"},
                            {**plan.widths, region: width})
        old_meta = {pe.pe_id: pe.graph_metadata for pe in plan.pes}
        affected = [pe for pe in new_plan.pes
                    if old_meta.get(pe.pe_id) != pe.graph_metadata]
        removed = [pe for pe in plan.pes if pe.pe_id >= len(new_plan.pes)]
        if drain:
            from .pipeline import drain_handoff
            removed_ids = {pe.pe_id for pe in removed}
            drainers = []
            for pe in removed:
                entry = self.pes.get((job, pe.pe_id))
                if entry is None:
                    continue
                rt, _stop, _pe = entry
                meta = pe.graph_metadata
                upstream = sorted({src[0] for port in meta["inputs"]
                                   for src in port["from"]
                                   if src[0] in removed_ids})
                self.fabric.set_draining(job, pe.pe_id)
                rt.begin_drain({"timeout": 5.0, "grace": 0.3,
                                "upstream": upstream,
                                **drain_handoff(new_plan, meta)})
                drainers.append(rt)
            for rt in drainers:  # synchronous: the monolith blocks
                rt.join(timeout=10)
        # sequential: stop all affected first...
        for pe in affected + removed:
            entry = self.pes.pop((job, pe.pe_id), None)
            if entry:
                rt, stop, _ = entry
                stop.set()
                rt.join(timeout=5)
            self.zk.delete_prefix(f"/jobs/{job}/pes/{pe.pe_id}")
        self.plans[job] = new_plan
        # ...then start replacements (new global ids)
        for pe in affected:
            gid = next(self._global_pe_ids)
            self.zk.put(f"/jobs/{job}/pes/{pe.pe_id}/gid", gid)
            for port in pe.input_ports:
                self.zk.put(f"/jobs/{job}/pes/{pe.pe_id}/in/{port['portId']}", port)
            self._start_pe(job, pe, new_plan)

    # ------------------------------------------------------------- cancel

    def cancel(self, job: str) -> None:
        for (j, pid), (rt, stop, _) in list(self.pes.items()):
            if j == job:
                stop.set()
        for (j, pid), (rt, stop, _) in list(self.pes.items()):
            if j == job:
                rt.join(timeout=5)
                del self.pes[(j, pid)]
        self.zk.delete_prefix(f"/jobs/{job}")
        self.plans.pop(job, None)
        self._job_status.pop(job, None)

    def kill_pe(self, job: str, pe_id: int) -> bool:
        entry = self.pes.get((job, pe_id))
        if not entry:
            return False
        rt, stop, pe = entry
        rt.crashed = True
        stop.set()  # note: _on_exit sees stop set -> emulate crash manually
        rt.join(timeout=5)
        self.connected.discard((job, pe_id))
        with self._lock:
            plan = self.plans.get(job)
            if plan and plan.consistent_region:
                self.fabric.abort_collectives(job)
            self._start_pe(job, pe, plan)
        return True

    def shutdown(self) -> None:
        for (j, pid), (rt, stop, _) in list(self.pes.items()):
            stop.set()
        for (j, pid), (rt, stop, _) in list(self.pes.items()):
            rt.join(timeout=5)
        self.pes.clear()


def _spec_with(plan) -> dict:
    """Reconstruct a minimal spec from a plan (legacy keeps specs around)."""
    model = plan.logical
    # the original spec is retained by callers in practice; benchmarks pass
    # the same spec to change_width via plans, so reconstruct the app block.
    trainer = next((op for op in model.ops if op.kind == "trainer"), None)
    if trainer is not None:
        return {"app": {"type": "train", **trainer.config},
                "consistentRegion": model.consistent_region}
    width = plan.widths.get("par", 2)
    depth = sum(1 for op in model.ops if op.region == "par")
    pre = sum(1 for op in model.ops if op.name.startswith("pre"))
    post = sum(1 for op in model.ops if op.name.startswith("post"))
    return {"app": {"type": "streams", "width": width, "pipeline_depth": depth,
                    "pre_ops": pre, "post_ops": post},
            "consistentRegion": model.consistent_region}
