"""The Streams instance operator: controllers, conductors, coordinators.

One instance operator per namespace (paper §5.1 — the legacy "domain" is
the cluster itself).  Actors communicate ONLY by creating / modifying /
deleting resources; Kubernetes-style event delivery (repro.core) does the
rest.  The causal chains from §4.4:

  1. PE creation        -> PE controller bumps launchCount (PE coordinator)
  2. voluntary PE delete-> PE controller recreates the PE  -> (1)
  3. pod failure/delete -> pod controller bumps launchCount (PE coordinator)
  4. generation change  -> job controller rewrites ConfigMaps; pod conductor
                           restarts only PEs whose metadata changed
  5. width decrease     -> retiring PE/Pod resources get the
                           ``streams/drain`` finalizer, the ``Draining``
                           condition, and a two-phase delete (the store
                           stamps ``deletion_timestamp``; the objects
                           linger).  The kubelet forwards the drain request
                           to the runtime + marks the fabric endpoints
                           drain-only; on the runtime's ``drained`` report
                           the pod conductor removes the finalizers and the
                           store reaps (immediately when draining is
                           disabled / no pod is running).
  6. job deletion       -> foreground cascade: owner-ref dependents reap
                           bottom-up, mid-drain branches held open by their
                           drain finalizers — no gc_collect fixed point on
                           the happy path (paper §8).
  *  pod conductor is the only actor that creates pods, and only in
     reaction to launchCount changes with all dependencies present.

Every spec/status write goes through the typed ``ApiClient`` (one
coordinator per kind): single-writer semantics by construction (§4.3).
"""

from __future__ import annotations

import itertools
import threading
import time

from ..ckpt import CheckpointStore
from ..core import (
    Conductor,
    ConflictError,
    Controller,
    Coordinator,
    Event,
    EventType,
    Resource,
    ResourceStore,
    condition_is,
    set_condition,
)
from . import crds
from .api import ApiClient, ensure_api
from .fabric import Fabric
from .pipeline import JobPlan, drain_handoff, plan_job
from .tracing import drain_token, migrate_token, pod_token, span_tracer


# ----------------------------------------------------------- REST facade


class RestFacade:
    """§5.2: the temporary REST layer PEs use to reach the platform.

    Every mutation goes through a coordinator — concurrent agents never
    write resources directly (§4.3).  Stands in for HTTP endpoints.
    """

    def __init__(self, store: ResourceStore, pod_coord: Coordinator,
                 ckpt: CheckpointStore, namespace: str = "default",
                 trace=None):
        self.store = store
        self.pod_coord = pod_coord
        self.ckpt = ckpt
        self.namespace = namespace
        self.trace = trace
        self.cr_operator = None  # wired by Platform
        self.broker = None
        self._last_metric: dict = {}
        # chaos clock-straggle windows: pod name -> (offset s, until monotonic).
        # While a window stands, the heartbeat this facade stamps on the
        # pod's metric reports lags wall clock by ``offset`` — the injected
        # equivalent of a kubelet whose clock (or report loop) straggles.
        self._straggle: dict = {}
        # process-isolation worker registry: node -> handshake info.  The
        # HostBridge records each worker process here when its hello lands,
        # so tests/operators can see which nodes run out-of-process.
        self.workers: dict = {}

    # ------------------------------------------- worker-process registration

    def register_worker(self, node: str, info: dict) -> None:
        self.workers[node] = dict(info, registeredAt=time.time())

    def unregister_worker(self, node: str) -> None:
        self.workers.pop(node, None)

    # ------------------------------------------------- chaos injection taps

    def straggle_heartbeat(self, job: str, pe_id: int, offset: float,
                           duration: float) -> None:
        """Arm a heartbeat-straggle window (chaos plane): for ``duration``
        seconds this pod's reported heartbeat lags by ``offset``, tripping
        the node pressure monitor's ``Straggling`` verdict and — past the
        job's ``stragglerTimeout`` — the straggler monitor."""
        self._straggle[crds.pod_name(job, pe_id)] = (
            float(offset), time.monotonic() + float(duration))

    def clear_straggle(self, job: str, pe_id: int) -> None:
        self._straggle.pop(crds.pod_name(job, pe_id), None)

    def _heartbeat(self, pod_name: str) -> float:
        entry = self._straggle.get(pod_name)
        if entry is not None:
            offset, until = entry
            if time.monotonic() < until:
                return time.time() - offset
            self._straggle.pop(pod_name, None)
        return time.time()

    def notify_connected(self, job: str, pe_id: int) -> None:
        pod_name = crds.pod_name(job, pe_id)
        # connect envelope: a replacement runtime can announce itself a
        # beat before the pod write that created it is observable on this
        # side — absorb that race with a short bounded backoff instead of
        # dropping the connected mark (which would wedge fullHealth)
        for attempt in range(3):
            if self.store.exists(crds.POD, pod_name, self.namespace):
                break
            time.sleep(0.02 * (attempt + 1))
        self.pod_coord.submit_status(pod_name,
                                     {"connected": True}, requester="pe-rest")
        sp = span_tracer(self.trace)
        if sp is not None:
            # a connected runtime is the end of any in-flight recovery span
            # for this pod (kill/crash/migration restart chains)
            sp.end_span(sp.detach(pod_token(crds.pod_name(job, pe_id))),
                        connected=True)

    def notify_standby_warm(self, job: str, pe_id: int,
                            step: int = -1) -> None:
        """A holding standby's readiness mark: sent once the runtime has
        paid its modeled boot and finished a warm pass, so ``StandbyReady``
        reflects a promotable runtime rather than a merely-started thread."""
        self.pod_coord.submit_status(
            crds.standby_pod_name(job, pe_id),
            {"warmed": True, "warmedStep": step}, requester="pe-rest")

    def notify_source_done(self, job: str, pe_id: int) -> None:
        self.pod_coord.submit_status(crds.pod_name(job, pe_id),
                                     {"sourceDone": True}, requester="pe-rest")

    def report_metrics(self, job: str, pe_id: int, metrics: dict) -> None:
        """Throttled load-sample ingestion; a sample marked ``final`` (a
        draining PE's last drop accounting) bypasses the throttle — it must
        not be swallowed."""
        key = (job, pe_id)
        now = time.monotonic()
        if not metrics.get("final") and \
                now - self._last_metric.get(key, 0.0) < 0.2:
            return
        self._last_metric[key] = now
        pod_name = crds.pod_name(job, pe_id)
        self.pod_coord.submit_status(
            pod_name,
            {"metrics": metrics, "heartbeat": self._heartbeat(pod_name)},
            requester="pe-rest")

    def report_sink(self, job: str, pe_id: int, seen: int, maxseq: int) -> None:
        self.pod_coord.submit_status(
            crds.pod_name(job, pe_id),
            {"sink": {"seen": seen, "maxseq": maxseq}}, requester="pe-rest")

    def notify_checkpoint(self, job: str, region: str, pe_id: int, step: int) -> None:
        if self.cr_operator is not None:
            self.cr_operator.receive_checkpoint(job, region, pe_id, step)

    def get_cr_state(self, job: str, region: str) -> dict | None:
        res = self.store.try_get(crds.CONSISTENT_REGION,
                                 crds.cr_name(job, region), self.namespace)
        return dict(res.status) if res else None

    def get_routes(self, job: str, op_name: str) -> list:
        if self.broker is None:
            return []
        return self.broker.routes_for(job, op_name)

    def routes_epoch(self) -> int:
        """Subscription-broker generation: senders cache their pub/sub route
        set against this and only re-read ``get_routes`` when it moves
        (instead of re-matching + re-resolving per tuple)."""
        return self.broker.epoch if self.broker is not None else 0

    # ------------------------------------------------- metrics exposition

    _PROM_HELP = {
        "streams_job_throughput_tuples": ("gauge", "Sum of region throughputs (tuples/s)"),
        "streams_region_throughput_tuples": ("gauge", "Region tuple rate (tuples/s)"),
        "streams_region_backpressure": ("gauge", "Mean input-queue fill across the region"),
        "streams_job_tuples_dropped": ("counter", "Cumulative drain-fallback tuple drops"),
        "streams_job_delivery_latency_ms": ("gauge", "End-to-end delivery latency percentile (ms)"),
        "streams_slo_met": ("gauge", "1 when every SLO objective is within budget"),
        "streams_slo_violations": ("counter", "SLO evaluations that returned Violated"),
        "streams_slo_burn_rate": ("gauge", "violations / evaluations"),
        "streams_pe_resolve_retries": ("counter", "Endpoint resolves retried after partition timeouts"),
        "streams_pe_flush_retries": ("counter", "Peer flushes deferred into partition backoff"),
    }

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of every job's Metrics rollup
        and SLO ledger (the scrape endpoint a real deployment would serve
        at ``/metrics``; tests and benchmarks call it directly)."""
        samples: dict[str, list[str]] = {name: [] for name in self._PROM_HELP}

        def add(metric: str, labels: dict, value) -> None:
            if value is None:
                return
            lbl = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            samples[metric].append(f"{metric}{{{lbl}}} {value}")

        for res in self.store.list(crds.METRICS, self.namespace):
            job = res.spec.get("job", res.name)
            st = res.status
            total = 0.0
            for region, agg in (st.get("regions") or {}).items():
                total += agg.get("throughput", 0.0)
                add("streams_region_throughput_tuples",
                    {"job": job, "region": region},
                    round(agg.get("throughput", 0.0), 3))
                add("streams_region_backpressure",
                    {"job": job, "region": region},
                    round(agg.get("backpressure", 0.0), 4))
            add("streams_job_throughput_tuples", {"job": job}, round(total, 3))
            add("streams_job_tuples_dropped", {"job": job},
                st.get("tuplesDropped", 0))
            for q, key in (("0.5", "latencyP50"), ("0.95", "latencyP95"),
                           ("0.99", "latencyP99")):
                add("streams_job_delivery_latency_ms",
                    {"job": job, "quantile": q}, st.get(key))
        for res in self.store.list(crds.SLO, self.namespace):
            job = res.spec.get("job", res.name)
            ledger = res.status.get("ledger") or {}
            met = next((c for c in res.status.get("conditions", ())
                        if c.get("type") == crds.COND_SLO_MET), None)
            if met is not None:
                add("streams_slo_met", {"job": job},
                    1 if met.get("status") == "True" else 0)
            add("streams_slo_violations", {"job": job},
                ledger.get("violations"))
            add("streams_slo_burn_rate", {"job": job}, ledger.get("burnRate"))
        for res in self.store.list(crds.POD, self.namespace):
            m = res.status.get("metrics") or {}
            if "resolveRetries" not in m and "flushRetries" not in m:
                continue
            labels = {"job": res.spec.get("job", ""),
                      "pe": res.spec.get("peId", "")}
            add("streams_pe_resolve_retries", labels, m.get("resolveRetries"))
            add("streams_pe_flush_retries", labels, m.get("flushRetries"))
        lines = []
        for metric, (mtype, help_text) in self._PROM_HELP.items():
            if not samples[metric]:
                continue
            lines.append(f"# HELP {metric} {help_text}")
            lines.append(f"# TYPE {metric} {mtype}")
            lines.extend(samples[metric])
        return "\n".join(lines) + ("\n" if lines else "")


# ------------------------------------------------------------ controllers


def downstream_pes(store, ns: str, job: str, meta: dict) -> list:
    """Transitive downstream closure of a PE (by its graph metadata):
    every PE a tuple leaving it could still have to traverse.  Walks the
    stored ConfigMaps, so it reflects the topology the running pods
    actually serve."""
    seen: set = set()
    frontier = {dst[0] for port in meta.get("outputs", ())
                for dst in port.get("to", ())}
    while frontier:
        pe_id = frontier.pop()
        if pe_id in seen:
            continue
        seen.add(pe_id)
        cm = store.try_get(crds.CONFIG_MAP, crds.cm_name(job, pe_id), ns)
        if cm is None:
            continue
        for port in cm.spec.get("data", {}).get("outputs", ()):
            frontier.update(dst[0] for dst in port.get("to", ())
                            if dst[0] not in seen)
    return sorted(seen)


def release_drain_holds(api: ApiClient, job: str, retiring_pe: int,
                        downstream: list) -> None:
    """Drop the retiring PE's delivery-path holds: each downstream pod
    loses this drain from its ``drainHolds`` ledger and, when the ledger
    empties, its ``streams/path-hold`` finalizer.  Whether the pod then
    reaps is the store's call — it may still carry its own
    ``streams/drain`` (or the cascade's foreground) finalizer."""
    for pe_id in downstream:
        def release(res: Resource) -> None:
            res.status["drainHolds"] = [
                h for h in res.status.get("drainHolds", ())
                if h != retiring_pe]
            if not res.status["drainHolds"] and \
                    crds.PATH_HOLD_FINALIZER in res.finalizers:
                res.finalizers.remove(crds.PATH_HOLD_FINALIZER)

        api.pods.edit(crds.pod_name(job, pe_id), release,
                      requester="drain-release")


def retire_pe(api: ApiClient, job: str, pe_id: int) -> None:
    """Remove a retired PE's resource set (pe + pod + cm + svc).

    The PE resource goes FIRST so the pod deletion that follows does not
    look voluntary: with the PE gone, the pod controller has no owner to
    bump a launchCount on and nothing is recreated.

    Finalizer-aware and idempotent: this is the completion path of the
    PE's OWN drain — its delivery-path holds on downstream pods are
    released and each resource's ``streams/drain`` finalizer is removed.
    A pod still holding the delivery path of ANOTHER in-flight drain keeps
    its separate ``streams/path-hold`` finalizer, so the store reaps it
    only when that drain completes too — one finalizer per obligation.
    """
    pod = api.pods.try_get(crds.pod_name(job, pe_id))
    if pod is not None:
        downstream = (pod.status.get("draining") or {}).get("downstream", ())
        release_drain_holds(api, job, pe_id, downstream)
    for handle, name in ((api.pes, crds.pe_name(job, pe_id)),
                         (api.pods, crds.pod_name(job, pe_id)),
                         (api.config_maps, crds.cm_name(job, pe_id)),
                         (api.services, crds.service_name(job, pe_id))):
        res = handle.try_get(name)
        if res is None:
            continue
        if not res.terminating:
            handle.delete(name)  # reaps, or stamps if finalized
        handle.remove_finalizer(name, crds.DRAIN_FINALIZER,
                                requester="retire")


class JobController(Controller):
    """Runs the submission pipeline; owns Job + all derived resources."""

    def __init__(self, store, namespace, coords, trace=None, fabric=None,
                 api=None):
        super().__init__(store, crds.JOB, namespace, "job-controller", trace)
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)
        # control-plane metadata only (publish counts for drain requests);
        # the controller never touches tuple data
        self.fabric = fabric
        self._ids = itertools.count(1)
        # local, ephemeral context (paper §6.1): lost on restart, recomputed
        self.ctx: dict = {}

    # -- causal link: Job ADDED -> assign id, mark Submitting
    def on_addition(self, job: Resource) -> None:
        if job.status.get("state"):  # controller restart replay
            self.ctx[job.name] = {"applied": job.status.get("appliedGeneration", 0)}
            return
        self.ctx[job.name] = {"applied": 0}
        job_id = next(self._ids)

        def mark(res: Resource) -> None:
            res.status.update(state="Submitting", jobId=job_id)
            res.spec.setdefault("widths", {})

        self.api.jobs.edit(job.name, mark, requester=self.name)

    # -- causal link: own Submitting write confirmed -> create resources;
    #    widths/generation change -> re-run the pipeline (§6.3)
    def on_modification(self, old, new: Resource) -> None:
        if new.terminating:  # teardown in flight: never re-plan under it
            return
        state = new.status.get("state")
        if state not in ("Submitting", "Submitted"):
            return
        ctx = self.ctx.setdefault(new.name, {"applied": 0})
        if ctx["applied"] >= new.generation:
            return
        ctx["applied"] = new.generation
        plan = plan_job(new.name, new.spec, new.spec.get("widths") or None,
                        generation=new.generation)
        try:
            self._apply_plan(new, plan)
        except ConflictError:
            # a teardown cascade stamped the job under this re-plan (the
            # store refuses dependents of a terminating owner) — the
            # cascade wins; anything genuinely conflicting is re-raised
            job = self.store.try_get(crds.JOB, new.name, new.namespace)
            if job is not None and not job.terminating:
                raise
            return

        def stamp(res: Resource) -> None:
            res.status["appliedGeneration"] = new.generation
            res.status["expectedPEs"] = len(plan.pes)

        self.api.jobs.edit(new.name, stamp, requester=self.name)

    def _apply_plan(self, job: Resource, plan: JobPlan) -> None:
        ns = job.namespace
        store = self.store
        # widths go only into PEs whose runtime *uses* them (trainer
        # collective width, reducer fan-in): putting them everywhere
        # would change every CM on a width edit and restart every pod,
        # defeating §6.3's only-restart-what-changed property.
        new_data: dict = {}
        restarting: set = set()  # surviving PEs whose metadata will change
        for pe in plan.pes:
            needs_widths = any(o.kind in ("trainer", "reducer")
                               for o in pe.operators)
            data = {**pe.graph_metadata,
                    "widths": plan.widths if needs_widths else {},
                    "consistentRegion": plan.consistent_region}
            new_data[pe.pe_id] = data
            cm = store.try_get(crds.CONFIG_MAP, crds.cm_name(job.name, pe.pe_id),
                               ns)
            if cm is not None and cm.spec["data"] != data:
                restarting.add(pe.pe_id)
        # Drain marks BEFORE the ConfigMap rewrites: the retiring PEs'
        # publish-count baselines must be captured before the pod conductor
        # starts restarting their surviving upstreams, or a drain could
        # wait on a restart that already happened.
        self._retire_beyond_plan(job, plan, restarting)
        # ConfigMaps FIRST among the creations (pod dependencies — the pod
        # conductor gates on them).  ``apply`` is create-or-replace with
        # spec merge, so the §6.3 create-or-update dance is one verb.
        for pe in plan.pes:
            data = new_data[pe.pe_id]
            name = crds.cm_name(job.name, pe.pe_id)
            existing = store.try_get(crds.CONFIG_MAP, name, ns)
            if existing is None or existing.spec["data"] != data or \
                    existing.spec.get("jobGeneration") != job.generation:
                self.api.config_maps.apply(
                    crds.make_config_map(job.name, pe.pe_id, data,
                                         job.generation, ns),
                    requester=self.name)
        for pe in plan.pes:
            name = crds.service_name(job.name, pe.pe_id)
            if not store.exists(crds.SERVICE, name, ns):
                self.api.services.create(crds.make_service(
                    job.name, pe.pe_id,
                    [p["portId"] for p in pe.input_ports], ns))
        # aux CRDs
        for region, width in plan.widths.items():
            name = crds.pr_name(job.name, region)
            if not store.exists(crds.PARALLEL_REGION, name, ns):
                self.api.parallel_regions.create(
                    crds.make_parallel_region(job.name, region, width, ns))
        if plan.consistent_region:
            region = plan.consistent_region.get("name", "region")
            # members = stateful region participants: trainers, and sources
            # that own an offset.  A train app's data op is stateless by
            # design (batches are computed, not stored) and never checkpoints.
            members = [pe.pe_id for pe in plan.pes
                       if any(o.in_region_cr and
                              (o.kind == "trainer" or
                               (o.kind == "source" and
                                o.config.get("role") != "data"))
                              for o in pe.operators)]
            name = crds.cr_name(job.name, region)
            if not store.exists(crds.CONSISTENT_REGION, name, ns):
                self.api.consistent_regions.create(crds.make_consistent_region(
                    job.name, region,
                    {**plan.consistent_region, "members": members}, ns))
            else:
                self.api.consistent_regions.patch(name, {"members": members},
                                                  requester=self.name)
        for op_name, stream, props in plan.exports:
            name = f"{job.name}-export-{op_name}"
            if not store.exists(crds.EXPORT, name, ns):
                pe = next(p for p in plan.pes
                          if any(o.name == op_name for o in p.operators))
                res = crds.make_export(job.name, op_name, stream, props, ns)
                res.spec["peId"] = pe.pe_id
                self.api.exports.create(res)
        for op_name, sub in plan.imports:
            name = f"{job.name}-import-{op_name}"
            if not store.exists(crds.IMPORT, name, ns):
                pe = next(p for p in plan.pes
                          if any(o.name == op_name for o in p.operators))
                res = crds.make_import(job.name, op_name, sub, ns)
                res.spec["peId"] = pe.pe_id
                self.api.imports.create(res)
        # PEs LAST: their creation triggers the pod causal chain.
        # create-or-replace (paper §6.3): an existing PE whose operator set
        # changed gets its spec updated in place (the pod restart, if any,
        # flows from the ConfigMap diff, not from here).
        for pe in plan.pes:
            name = crds.pe_name(job.name, pe.pe_id)
            want = {"operators": [o.name for o in pe.operators],
                    "podSpec": pe.pod_spec}
            existing = store.try_get(crds.PE, name, ns)
            if existing is None:
                self.api.pes.create(crds.make_pe(job.name, pe.pe_id, want, ns))
            elif (existing.spec.get("operators") != want["operators"] or
                  existing.spec.get("podSpec") != want["podSpec"]):
                self.api.pes.patch(name, want, requester=self.name)

    def _retire_beyond_plan(self, job: Resource, plan: JobPlan,
                            restarting: set) -> None:
        """Width decrease: retire PEs beyond the plan.  A retiring PE with a
        live pod is not hard-deleted — PE and pod get the ``streams/drain``
        finalizer, the ``Draining`` condition, and a drain request (handoff
        targets computed from the NEW generation's plan), and are then
        two-phase deleted: the store stamps ``deletion_timestamp`` and the
        objects linger until the runtime's ``drained`` report removes the
        finalizer (the pod conductor's completion path).  Without a live
        pod (deterministic mode, or draining disabled) retirement is
        immediate, the seed drop behaviour."""
        ns = job.namespace
        store = self.store
        drain_cfg = crds.drain_config(job.spec)
        retiring = {pe_res.spec["peId"]: pe_res
                    for pe_res in store.list(crds.PE, ns,
                                             crds.job_labels(job.name))
                    if pe_res.spec["peId"] >= len(plan.pes)}
        # arm DOWNSTREAM drainers first (ids are topologically ordered
        # within a channel): if a teardown cascade races this loop, the
        # not-yet-armed PEs it hard-kills are upstream of every armed
        # drainer — an armed drainer never ends up flushing into a peer
        # the teardown already tore out from under it
        for pe_id, pe_res in sorted(retiring.items(), reverse=True):
            pod = store.try_get(crds.POD, crds.pod_name(job.name, pe_id), ns)
            drainable = (drain_cfg["enabled"] and pod is not None
                         and pod.status.get("phase") == "Running")
            if not drainable:
                if pod is not None and pod.status.get("draining"):
                    continue  # a previous generation's drain is in flight
                retire_pe(self.api, job.name, pe_id)
                continue
            if pod.status.get("draining") or pod.terminating:
                continue  # already draining; the finalizer completes it
            cm = store.try_get(crds.CONFIG_MAP, crds.cm_name(job.name, pe_id),
                               ns)
            meta = cm.spec.get("data", {}) if cm is not None else {}
            handoff = drain_handoff(plan, meta)
            # upstreams of this PE gate its "input dry" condition: retiring
            # ones must unpublish (their final flush precedes unpublish),
            # restarting survivors must publish their NEW incarnation
            # (which happens strictly after the old one's final flush) —
            # baseline publish counts are captured here, before any restart
            upstream_pes = {src[0] for port in meta.get("inputs", ())
                            for src in port.get("from", ())}
            upstream = sorted(p for p in upstream_pes if p in retiring)
            upstream_restarting = sorted(
                [p, self.fabric.publish_count(job.name, p)]
                for p in upstream_pes
                if p in restarting) if self.fabric is not None else []
            # delivery-path holds: every pod downstream of the drainer gets
            # the drain finalizer + a ledger entry, so a job teardown that
            # lands mid-drain cannot reap the path the drained tuples still
            # need (released with the drained report) — zero loss even when
            # the cascade races the drain
            downstream = [d for d in downstream_pes(store, ns, job.name, meta)
                          if d not in retiring and d < len(plan.pes)]
            drain_request = {"requestedAt": time.time(),
                             "timeout": drain_cfg["timeout"],
                             "grace": drain_cfg["grace"],
                             "upstream": upstream,
                             "upstreamRestarting": upstream_restarting,
                             "downstream": downstream,
                             **handoff}
            for d in downstream:
                def hold(res: Resource, pe=pe_id) -> None:
                    if res.terminating:
                        return  # too late to extend its life (store rule)
                    holds = list(res.status.get("drainHolds", ()))
                    if pe not in holds:
                        holds.append(pe)
                    res.status["drainHolds"] = holds
                    if crds.PATH_HOLD_FINALIZER not in res.finalizers:
                        res.finalizers.append(crds.PATH_HOLD_FINALIZER)

                self.api.pods.edit(crds.pod_name(job.name, d), hold,
                                   requester=self.name)

            def mark_pe(res: Resource) -> None:
                if res.terminating and \
                        crds.DRAIN_FINALIZER not in res.finalizers:
                    return  # a teardown got here first; it owns the PE now
                if crds.DRAIN_FINALIZER not in res.finalizers:
                    res.finalizers.append(crds.DRAIN_FINALIZER)
                res.status["state"] = "Draining"
                set_condition(res, crds.COND_DRAINING, "True",
                              reason="ScaleDown")

            def mark_pod(res: Resource, req=drain_request) -> None:
                if res.terminating and \
                        crds.DRAIN_FINALIZER not in res.finalizers:
                    return  # too late to arm: the finalizer can't be added
                if crds.DRAIN_FINALIZER not in res.finalizers:
                    res.finalizers.append(crds.DRAIN_FINALIZER)
                res.status["draining"] = req
                set_condition(res, crds.COND_DRAINING, "True",
                              reason="ScaleDown")

            pod_name = crds.pod_name(job.name, pe_id)
            sp = span_tracer(self.trace)
            if sp is not None:
                # root of the drain span tree; attached BEFORE the arming
                # edits so the kubelet's begin-drain (reacting to the status
                # event on its own thread) finds the context
                sp.attach(drain_token(pod_name),
                          sp.start_span(self.name, "drain", pe_res.key,
                                        job=job.name, pe=pe_id))
            self.api.pes.edit(pe_res.name, mark_pe, requester=self.name)
            armed = self.api.pods.edit(pod_name, mark_pod,
                                       requester=self.name)
            if armed is None or not armed.status.get("draining") or \
                    crds.DRAIN_FINALIZER not in armed.finalizers:
                # a teardown cascade raced the arming: without the finalizer
                # + drain request no drained report will ever release the
                # delivery-path holds — roll them back and stand aside
                if sp is not None:
                    sp.end_span(sp.detach(drain_token(pod_name)),
                                aborted="teardown-raced-arming")
                release_drain_holds(self.api, job.name, pe_id, downstream)
                continue
            # the retirement IS a deletion: two-phase — the finalizer keeps
            # the objects (and the drain machinery) alive until drained
            self.api.pes.delete(pe_res.name)
            self.api.pods.delete(pod_name)
            self._record("drain", pe_res.key,
                         f"siblings={handoff['siblings']}")

    # -- teardown.  The happy path is foreground cascade deletion (the
    # store walks owner references, holding mid-drain branches open on
    # their finalizers) — this callback fires at the job's reap, after the
    # cascade already emptied the subtree.  ``gcMode: "manual"`` keeps the
    # §8 bulk-label sweep for orphan-propagated deletes.
    def on_deletion(self, job: Resource) -> None:
        if job.spec.get("gcMode") == "manual":
            self.store.delete_collection(namespace=job.namespace,
                                         label_selector=crds.job_labels(job.name))
        self.ctx.pop(job.name, None)


class PEController(Controller):
    def __init__(self, store, namespace, coords, trace=None):
        super().__init__(store, crds.PE, namespace, "pe-controller", trace)
        self.coords = coords

    # causal link 1: new PE -> bump launch count
    def on_addition(self, pe: Resource) -> None:
        self.coords["pe"].submit(
            pe.name, lambda r: r.status.update(
                launchCount=r.status.get("launchCount", 0) + 1),
            requester=self.name)

    # causal link 2: voluntary deletion -> recreate (if still expected)
    def on_deletion(self, pe: Resource) -> None:
        job = self.store.try_get(crds.JOB, pe.spec["job"], pe.namespace)
        if job is None or job.terminating or \
                job.status.get("state") not in ("Submitted", "Submitting"):
            return
        plan = plan_job(job.name, job.spec, job.spec.get("widths") or None,
                        generation=job.generation)
        if pe.spec["peId"] < len(plan.pes):
            fresh = crds.make_pe(job.name, pe.spec["peId"],
                                 {k: v for k, v in pe.spec.items()
                                  if k not in ("job", "peId")}, pe.namespace)
            try:
                self.store.create(fresh)
            except Exception:
                pass


class PodController(Controller):
    """Overrides kubelet restart: failures route through the PE coordinator."""

    def __init__(self, store, namespace, coords, trace=None, api=None):
        super().__init__(store, crds.POD, namespace, "pod-controller", trace)
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)

    # causal link 3a: pod failure -> bump owning PE launch count
    def on_modification(self, old, new: Resource) -> None:
        if new.spec.get("standby"):
            # standby pods belong to the failover conductor: their failure
            # re-warms a replacement standby, never the restart chain
            return
        was = (old.status.get("phase") if old else None)
        if new.status.get("phase") == "Failed" and was != "Failed":
            if new.status.get("drainHolds"):
                # a dead pod cannot serve the delivery path its hold was
                # protecting — drop the hold so the restart chain can free
                # the name and recreate it (the fabric's residual carryover
                # preserves its ring across the restart; keeping the corpse
                # would stall the drain into its timeout instead)
                def clear_holds(res: Resource) -> None:
                    res.status["drainHolds"] = []
                    if crds.PATH_HOLD_FINALIZER in res.finalizers:
                        res.finalizers.remove(crds.PATH_HOLD_FINALIZER)

                self.api.pods.edit(new.name, clear_holds,
                                   requester=self.name)
            self.store.try_delete(crds.POD, new.name, new.namespace)
            self._bump(new)

    # causal link 3b: pod deletion while PE alive -> bump launch count
    def on_deletion(self, pod: Resource) -> None:
        if pod.spec.get("standby"):
            return
        pe_name = crds.pe_name(pod.spec["job"], pod.spec["peId"])
        pe = self.store.try_get(crds.PE, pe_name, pod.namespace)
        if pe is not None:
            self._bump(pod)

    def _bump(self, pod: Resource) -> None:
        pe_name = crds.pe_name(pod.spec["job"], pod.spec["peId"])
        pe = self.store.try_get(crds.PE, pe_name, pod.namespace)
        if pe is not None and (pe.terminating or
                               pe.status.get("state") == "Draining"):
            # a draining/terminating PE that fails/vanishes is not
            # restarted — it was leaving anyway; finish the retirement
            # (drop its finalizers) instead of resurrecting it
            retire_pe(self.api, pod.spec["job"], pod.spec["peId"])
            self._record("retire-failed-drain", pod.key)
            return
        if pe is not None and condition_is(pe, crds.COND_QUARANTINED):
            # partitioned-but-alive: the runtime is healthy, only its
            # fabric reach is cut.  Restarting it would turn a transient
            # partition into real data loss — senders are already backing
            # off and re-buffering.  The quarantine lift re-kicks the
            # launch chain if the pod really is gone by then.
            self._record("skip-bump-quarantined", pod.key)
            return
        if pe is not None and (condition_is(pe, crds.COND_STANDBY_READY)
                               or condition_is(pe, crds.COND_PROMOTING)):
            # a warm standby stands (or its promotion is already in
            # flight): the failover conductor owns this failure — a bump
            # here would race a cold restart against the promotion
            self._record("skip-bump-standby", pod.key)
            return
        sp = span_tracer(self.trace)
        if sp is not None and sp.context(pod_token(pod.name)) is None:
            # recovery span root (unless chaos already opened one at the
            # kill): failure detected -> replacement connected.  Parented
            # under an in-flight migration of this PE, if any.
            sp.attach(pod_token(pod.name),
                      sp.start_span(self.name, "recover", pod.key,
                                    parent=sp.context(migrate_token(pe_name)),
                                    job=pod.spec["job"],
                                    pe=pod.spec["peId"]))
        self.coords["pe"].submit(
            pe_name, lambda r: r.status.update(
                launchCount=r.status.get("launchCount", 0) + 1),
            requester=self.name)


class ParallelRegionController(Controller):
    """Width edits feed the normal submission path via the job coordinator."""

    def __init__(self, store, namespace, coords, trace=None):
        super().__init__(store, crds.PARALLEL_REGION, namespace,
                         "parallelregion-controller", trace)
        self.coords = coords

    def on_modification(self, old, new: Resource) -> None:
        if old and old.spec.get("width") == new.spec.get("width"):
            return
        job, region, width = new.spec["job"], new.spec["region"], new.spec["width"]

        def set_width(res: Resource) -> None:
            widths = dict(res.spec.get("widths") or {})
            widths[region] = width
            res.spec["widths"] = widths  # spec change -> generation++

        self.coords["job"].submit(job, set_width, requester=self.name)


class ImportController(Controller):
    def __init__(self, store, namespace, trace=None):
        super().__init__(store, crds.IMPORT, namespace, "import-controller", trace)


class ExportController(Controller):
    def __init__(self, store, namespace, trace=None):
        super().__init__(store, crds.EXPORT, namespace, "export-controller", trace)


class ConsistentRegionController(Controller):
    def __init__(self, store, namespace, trace=None):
        super().__init__(store, crds.CONSISTENT_REGION, namespace,
                         "consistentregion-controller", trace)


# ------------------------------------------------------------- conductors


class PodConductor(Conductor):
    """The ONLY creator of pods.  Reacts to PE launchCount changes; gates on
    ConfigMap + Service existence; restarts pods whose graph metadata
    changed across generations (identical metadata -> no restart, §6.3)."""

    kinds = (crds.PE, crds.CONFIG_MAP, crds.POD, crds.SERVICE)

    def __init__(self, store, namespace, coords, trace=None, api=None):
        super().__init__(store, "pod-conductor", trace)
        self.namespace = namespace
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)
        self._cm_seen: dict = {}  # cm name -> last graph data applied

    def on_event(self, event: Event) -> None:
        res = event.resource
        if res.kind == crds.POD and event.type == EventType.MODIFIED and \
                res.status.get("drained") is not None:
            # act on the drained TRANSITION (or whenever the finalizer is
            # still pending — replay / a partially-failed finalization),
            # not on every later status write to the lingering pod
            if event.old is None or \
                    event.old.status.get("drained") is None or \
                    crds.DRAIN_FINALIZER in res.finalizers:
                self._finalize_drained(res)
            return
        if res.kind == crds.PE and event.type != EventType.DELETED:
            self._reconcile_pe(res)
        elif res.kind == crds.SERVICE and event.type == EventType.ADDED:
            pe = self.store.try_get(crds.PE, crds.pe_name(
                res.spec["job"], res.spec["peId"]), self.namespace)
            if pe:
                self._reconcile_pe(pe)
        elif res.kind == crds.CONFIG_MAP:
            self._reconcile_cm(event, res)

    def _finalize_drained(self, pod: Resource) -> None:
        """Drain complete: the ``drained`` report is the ``streams/drain``
        finalizer's removal trigger — dropping it lets the store reap the
        two-phase-deleted PE/pod (the §6.3 chain's new last link).  Gated
        on the PE actually draining so a stray ``drained`` status cannot
        take down a live PE."""
        job, pe_id = pod.spec["job"], pod.spec["peId"]
        pe = self.store.try_get(crds.PE, crds.pe_name(job, pe_id),
                                self.namespace)
        if pe is None or not (pe.terminating or
                              pe.status.get("state") == "Draining"):
            return
        stats = pod.status.get("drained") or {}
        sp = span_tracer(self.trace)
        root = sp.context(drain_token(pod.name)) if sp is not None else None
        retire_span = sp.start_span(self.name, "retire", pod.key,
                                    parent=root) if sp is not None else None
        self.api.pods.edit(
            pod.name,
            lambda r: set_condition(
                r, crds.COND_DRAINED, "True",
                reason="Clean" if stats.get("clean") else "Timeout",
                message=f"dropped={stats.get('tuplesDropped', 0)}"),
            requester=self.name)
        retire_pe(self.api, job, pe_id)
        if sp is not None:
            sp.end_span(retire_span,
                        dropped=stats.get("tuplesDropped", 0),
                        handedOff=stats.get("handedOff", 0))
            sp.end_span(sp.detach(drain_token(pod.name)),
                        clean=stats.get("clean", False),
                        drainMs=stats.get("drainMs", 0.0),
                        dropped=stats.get("tuplesDropped", 0))
        self._record("retire", pod.key,
                     f"dropped={stats.get('tuplesDropped', 0)};"
                     f"handedOff={stats.get('handedOff', 0)}")

    def _reconcile_pe(self, pe: Resource) -> None:
        job, pe_id = pe.spec["job"], pe.spec["peId"]
        if pe.terminating or pe.status.get("state") == "Draining":
            return  # a retiring/terminating PE never gets a fresh pod
        if condition_is(pe, crds.COND_PROMOTING):
            # the failover conductor is converging the pod records itself;
            # reconciling here would double-create the primary's pod
            return
        want = pe.status.get("launchCount", 0)
        if want < 1:
            return
        cm = self.store.try_get(crds.CONFIG_MAP, crds.cm_name(job, pe_id),
                                self.namespace)
        svc = self.store.try_get(crds.SERVICE, crds.service_name(job, pe_id),
                                 self.namespace)
        if cm is None or svc is None:
            return  # dependencies not ready; later events re-trigger
        pod = self.store.try_get(crds.POD, crds.pod_name(job, pe_id),
                                 self.namespace)
        if pod is not None and pod.spec.get("launchCount", 0) >= want:
            return
        if pod is not None:
            # stale pod for an older launch: delete, recreate on next event
            self.store.try_delete(crds.POD, pod.name, self.namespace)
            return
        new_pod = crds.make_pod(job, pe_id, {"pod_spec": pe.spec.get("podSpec", {})},
                                want, cm.spec.get("jobGeneration", 1),
                                self.namespace)
        try:
            self.api.pods.create(new_pod)
            self._record("create", new_pod.key, f"launch={want}")
        except Exception:
            pass

    def _reconcile_cm(self, event: Event, cm: Resource) -> None:
        key = cm.name
        data = cm.spec.get("data")
        prev = self._cm_seen.get(key)
        self._cm_seen[key] = data
        if event.type != EventType.MODIFIED or prev is None:
            return
        if prev == data:
            # identical metadata: bump the pod's generation, no restart
            def bump(res: Resource) -> None:
                res.spec["jobGeneration"] = cm.spec.get("jobGeneration", 1)

            self.coords["pod"].submit(crds.pod_name(cm.spec["job"],
                                                    cm.spec["peId"]),
                                      bump, requester=self.name)
            return
        # changed metadata -> restart via causal chain: delete pod; pod
        # controller bumps launchCount; this conductor recreates
        self.store.try_delete(crds.POD, crds.pod_name(cm.spec["job"],
                                                      cm.spec["peId"]),
                              self.namespace)


class JobConductor(Conductor):
    """Tracks submission/health/termination state (recomputable only)."""

    kinds = (crds.JOB, crds.PE, crds.POD, crds.CONFIG_MAP, crds.SERVICE)

    def __init__(self, store, namespace, coords, trace=None, api=None):
        super().__init__(store, "job-conductor", trace)
        self.namespace = namespace
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)

    def on_event(self, event: Event) -> None:
        res = event.resource
        job_name = res.name if res.kind == crds.JOB else res.spec.get("job")
        if not job_name:
            return
        job = self.store.try_get(crds.JOB, job_name, self.namespace)
        if job is None or job.terminating:
            return  # teardown in flight: no further life-cycle churn
        expected = job.status.get("expectedPEs")
        if expected is None:
            return
        pes = self.store.list(crds.PE, self.namespace, crds.job_labels(job_name))
        pods = self.store.list(crds.POD, self.namespace, crds.job_labels(job_name))
        patch: dict = {}
        conds: list = []  # (type, status, reason)
        if (job.status.get("state") == "Submitting" and len(pes) >= expected):
            patch.update(state="Submitted", submittedAt=time.time())
            conds.append((crds.COND_SUBMITTED, "True", "PipelineApplied"))
        healthy = [p for p in pods
                   if (p.status.get("phase") == "Running" and p.status.get("connected"))
                   or p.status.get("phase") == "Succeeded"]
        full = (len(healthy) >= expected and len(pods) >= expected)
        if full and not job.status.get("fullHealth"):
            patch.update(fullHealth=True, fullHealthAt=time.time())
            conds.append((crds.COND_FULL_HEALTH, "True", "AllPodsHealthy"))
        elif not full and job.status.get("fullHealth"):
            patch.update(fullHealth=False)
            conds.append((crds.COND_FULL_HEALTH, "False",
                          f"healthy={len(healthy)}/{expected}"))
        done = [p for p in pods if p.status.get("phase") == "Succeeded"
                or p.status.get("sourceDone")]
        if done and job.status.get("state") == "Submitted":
            src_pes = [p for p in pods if p.status.get("sourceDone")]
            if src_pes:
                patch.setdefault("sourcesDone", len(src_pes))
        if patch or conds:
            def write(res: Resource, patch=patch, conds=conds) -> None:
                res.status.update(patch)
                for ctype, status, reason in conds:
                    # observedGeneration defaults to the generation current
                    # at write time — consumers can spot stale conditions
                    set_condition(res, ctype, status, reason=reason)

            self.api.jobs.edit(job_name, write, requester=self.name)


class SubscriptionBroker(Conductor):
    """§6.4: matches Import/Export CRDs; its board is recomputable state."""

    kinds = (crds.IMPORT, crds.EXPORT)

    def __init__(self, store, namespace, fabric: Fabric, trace=None):
        super().__init__(store, "subscription-broker", trace)
        self.namespace = namespace
        self.fabric = fabric
        self._lock = threading.Lock()
        self._exports: dict = {}  # (job, op) -> (stream, props, peId)
        self._imports: dict = {}  # (job, op) -> (subscription, peId)
        self._routes: dict = {}  # (exp job, exp op) -> [(imp job, peId)]
        self.epoch = 0  # bumped on every rematch; senders cache against it

    def on_event(self, event: Event) -> None:
        res = event.resource
        with self._lock:
            if res.kind == crds.EXPORT:
                key = (res.spec["job"], res.spec["operator"])
                if event.type == EventType.DELETED:
                    self._exports.pop(key, None)
                else:
                    self._exports[key] = (res.spec["stream"],
                                          res.spec.get("properties", {}),
                                          res.spec["peId"])
            elif res.kind == crds.IMPORT:
                key = (res.spec["job"], res.spec["operator"])
                if event.type == EventType.DELETED:
                    self._imports.pop(key, None)
                else:
                    self._imports[key] = (res.spec["subscription"],
                                          res.spec["peId"])
            self._rematch()

    @staticmethod
    def _matches(sub: dict, stream: str, props: dict) -> bool:
        if sub.get("stream"):
            return sub["stream"] == stream
        want = sub.get("properties", {})
        return bool(want) and all(props.get(k) == v for k, v in want.items())

    def _rematch(self) -> None:
        routes: dict = {}
        for (ejob, eop), (stream, props, _epe) in self._exports.items():
            for (ijob, _iop), (sub, ipe) in self._imports.items():
                if self._matches(sub, stream, props):
                    routes.setdefault((ejob, eop), []).append((ijob, ipe))
        self._routes = routes
        self.epoch += 1

    def routes_for(self, job: str, op_name: str) -> list:
        with self._lock:
            targets = list(self._routes.get((job, op_name), ()))
        out = []
        # wait out the DNS propagation window: senders cache this result
        # against the broker/fabric epochs, and the window elapsing bumps
        # neither — dropping a route here would pin it missing until some
        # unrelated publish happened
        timeout = 0.01 + self.fabric.dns_delay
        for ijob, ipe in targets:
            try:
                out.append(self.fabric.resolve(ijob, ipe, 0, timeout=timeout))
            except TimeoutError:
                pass
        return out


class StragglerMonitor:
    """Straggler mitigation: a pod that stops making progress is treated as
    failed — same causal chain as a crash (launchCount++ → recreate →
    consistent-region rollback picks up the replacement).

    Progress = the ``heartbeat`` timestamp PEs attach to their metric
    reports.  Scans are explicit (``scan()``) or driven by a daemon thread
    (``start``); only pods of jobs that opted in via
    ``spec.stragglerTimeout`` are eligible.
    """

    def __init__(self, store, namespace, pod_coord, trace=None):
        self.store = store
        self.namespace = namespace
        self.pod_coord = pod_coord
        self.trace = trace
        self._stop = threading.Event()
        self._thread = None

    def scan(self, now: float | None = None) -> list:
        now = time.time() if now is None else now
        marked = []
        for pod in self.store.list(crds.POD, self.namespace):
            if pod.status.get("phase") != "Running":
                continue
            if pod.spec.get("standby"):
                continue  # holding standbys report no progress by design
            job = self.store.try_get(crds.JOB, pod.spec.get("job"), self.namespace)
            if job is None:
                continue
            timeout = job.spec.get("stragglerTimeout")
            hb = pod.status.get("heartbeat")
            if not timeout or hb is None:
                continue
            pe = self.store.try_get(
                crds.PE, crds.pe_name(pod.spec["job"], pod.spec["peId"]),
                self.namespace)
            if pe is not None and condition_is(pe, crds.COND_QUARANTINED):
                continue  # partitioned, not dead: routed around, not failed
            if now - hb > timeout:
                self.pod_coord.submit_status(pod.name, {"phase": "Failed"},
                                             requester="straggler-monitor")
                if self.trace is not None:
                    self.trace.record("straggler-monitor", "mark-failed",
                                      pod.key, f"stale={now - hb:.1f}s")
                marked.append(pod.name)
        return marked

    def start(self, interval: float = 1.0) -> None:
        def loop():
            while not self._stop.is_set():
                try:
                    self.scan()
                except Exception:  # noqa: BLE001
                    pass
                self._stop.wait(interval)

        self._thread = threading.Thread(target=loop, name="straggler-monitor",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)


class ConsistentRegionOperator(Conductor):
    """§6.5: its own operator; coordinates checkpoints + rollback/recovery.

    Observes pod life-cycle events for region members; receives checkpoint
    notifications via the REST facade; commits a checkpoint id into the CR
    CRD only when every member reported it.  On a member failure it aborts
    the job's collective epochs (surviving shards rewind) — rollback —
    and the pod-restart causal chain performs recovery.
    """

    kinds = (crds.CONSISTENT_REGION, crds.POD)

    def __init__(self, store, namespace, coords, fabric: Fabric,
                 ckpt: CheckpointStore, trace=None):
        super().__init__(store, "consistentregion-operator", trace)
        self.namespace = namespace
        self.coords = coords
        self.fabric = fabric
        self.ckpt = ckpt
        self._lock = threading.Lock()
        self._pending: dict = {}  # (job, region, step) -> set(pe ids)

    def receive_checkpoint(self, job: str, region: str, pe_id: int, step: int) -> None:
        cr = self.store.try_get(crds.CONSISTENT_REGION,
                                crds.cr_name(job, region), self.namespace)
        if cr is None:
            return
        members = set(cr.spec.get("members", ()))
        with self._lock:
            got = self._pending.setdefault((job, region, step), set())
            got.add(pe_id)
            complete = members.issubset(got)
            if complete:
                for key in list(self._pending):
                    if key[:2] == (job, region) and key[2] <= step:
                        del self._pending[key]
        if complete and step > cr.status.get("lastCommitted", -1):
            # commit protocol: stamp the ``.committing`` marker BEFORE the
            # CRD status write so the conductor-driven sweep (failover
            # conductor, on the commit event) can never race this step
            # away; older uncommitted steps are ITS garbage, not ours
            self.ckpt.mark_committing(job, region, step)
            self.coords["cr"].submit_status(
                crds.cr_name(job, region),
                {"lastCommitted": step, "state": "Processing"},
                requester=self.name)
            self.ckpt.clear_committing(job, region, step)
            self._record("commit", cr.key, f"step={step}")

    def on_event(self, event: Event) -> None:
        res = event.resource
        if res.kind != crds.POD:
            return
        if res.spec.get("standby"):
            # a holding standby never joined the region's collectives;
            # losing it must not abort the live members' epochs
            return
        failed = (event.type == EventType.DELETED or
                  res.status.get("phase") == "Failed")
        if not failed:
            return
        job = res.spec.get("job")
        pe_id = res.spec.get("peId")
        for cr in self.store.list(crds.CONSISTENT_REGION, self.namespace,
                                  crds.job_labels(job)):
            if pe_id in cr.spec.get("members", ()):  # rollback
                self.fabric.abort_collectives(job)
                self.coords["cr"].submit_status(
                    cr.name, {"state": "Recovering"}, requester=self.name)
                self._record("rollback", cr.key, f"pe={pe_id}")
