"""Transport backends for the data-plane fabric.

``Transport`` is the seam between the fabric's name registry and how tuple
batches actually move.  Two backends:

- ``InprocTransport`` — the original deque-ring (``TupleQueue``).  Endpoints
  are the rings themselves; a put is one lock crossing.  Default, unchanged
  semantics.
- ``SocketTransport`` — every endpoint is still a ``TupleQueue`` ring on the
  *receiving* side, but puts travel as length-prefixed codec frames over a
  local TCP socket to a per-transport ``SocketHub``, which inserts into the
  ring and replies with an ACK carrying the ring's verdict (ok / full /
  shutdown + the admitted prefix).  The sender surface is byte-for-byte the
  ``TupleQueue`` put contract — same exceptions, same ``admitted``
  annotation, same counter accounting — so every sender-side code path
  (flush retry envelopes, drain carryover, partition re-buffering) runs
  unmodified over the wire.

Reconnects are lazy: a dead connection surfaces as ``Unreachable`` and the
next put dials fresh.  The capped-exponential pacing between attempts is
*not* re-implemented here — it rides the existing ``EndpointCache`` /
runtime flush retry envelopes, which already back off on ``Unreachable``.

The fabric's exception vocabulary (``ShutDown``, ``Unreachable``,
``EpochAborted``) and the ring itself live here now; ``fabric`` re-exports
them so existing imports keep working.
"""

from __future__ import annotations

import itertools
import queue
import socket
import threading
import time
from collections import deque

from .wire import (DEFAULT_MAX_FRAME, F_ACK, F_DATA, FrameDecoder, FrameError,
                   decode_value, encode_frame, encode_value)


class EpochAborted(Exception):
    def __init__(self, epoch: int):
        super().__init__(f"collective epoch aborted -> {epoch}")
        self.epoch = epoch


class ShutDown(Exception):
    pass


class Unreachable(TimeoutError):
    """Resolution failed because the peer is *partitioned*, not retired.

    Subclasses ``TimeoutError`` so unhardened callers degrade to the old
    behaviour, but a partition-aware sender can tell the two apart: an
    unreachable peer is alive behind a network fault and will come back —
    re-buffer and retry — while a retired peer is gone for good and the
    buffered tail is a legitimate counted drop."""


class TupleQueue:
    """Bounded blocking ring standing in for a PE-PE TCP connection.

    A deque guarded by one lock with separate not-empty / not-full
    conditions (so batch puts never wake other producers).  ``put_many`` /
    ``get_many`` move a whole batch under a single lock acquisition — the
    per-tuple cost of ``queue.Queue`` was the dominant term in the Fig. 8
    microbenchmark.  Capacity is accounted in tuples; a batch larger than
    the remaining room is admitted in chunks as the consumer drains.

    Instrumented for the metrics plane: cumulative enqueue/dequeue counters,
    batch counters (average batch size = tuples / batches), a depth
    high-watermark, and a count of puts that found insufficient room — the
    backpressure signal autoscaling acts on, counted once per batch.
    """

    def __init__(self, maxsize: int = 1024):
        self.capacity = maxsize if maxsize > 0 else 0  # 0 = unbounded
        self._items: deque = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self.closed = False
        self.enqueued = 0
        self.dequeued = 0
        self.high_watermark = 0
        self.blocked_puts = 0
        self.put_batches = 0
        self.get_batches = 0

    # ---------------------------------------------------------------- puts

    def put(self, item, timeout: float = 10.0) -> None:
        with self._lock:
            if self.closed:
                raise ShutDown
            if self.capacity and len(self._items) >= self.capacity:
                self.blocked_puts += 1
                self._wait_for_room(time.monotonic() + timeout)
            self._items.append(item)
            self.enqueued += 1
            self.put_batches += 1
            depth = len(self._items)
            if depth > self.high_watermark:
                self.high_watermark = depth
            self._not_empty.notify()

    def put_many(self, items, timeout: float = 10.0) -> None:
        """Enqueue a batch under one lock crossing.

        Blocks while the ring is full; raises ``queue.Full`` on timeout and
        ``ShutDown`` if the queue closes while waiting.  Backpressure is
        recorded once per batch that found insufficient room.  Delivery is
        best-effort on failure: a raise can leave a prefix of the batch
        admitted (already-enqueued tuples are in flight and not rolled
        back) — callers must not retry the same batch, they would duplicate
        the prefix.  The streaming contract absorbs this: outside a
        consistent region tuples are best-effort, inside one replay from
        the checkpoint repairs any loss.
        """
        if not isinstance(items, (list, tuple)):
            items = list(items)
        n = len(items)
        if n == 0:
            return
        deadline = time.monotonic() + timeout
        with self._lock:
            if self.closed:
                raise ShutDown
            if self.capacity and len(self._items) + n > self.capacity:
                self.blocked_puts += 1
            i = 0
            try:
                while i < n:
                    room = (self.capacity - len(self._items)) if self.capacity \
                        else (n - i)
                    if room <= 0:
                        try:
                            self._wait_for_room(deadline)
                        except (queue.Full, ShutDown) as e:
                            # callers that account per delivered tuple need
                            # the in-flight prefix (it is not rolled back)
                            e.admitted = i
                            raise
                        continue
                    take = min(room, n - i)
                    self._items.extend(items[i:i + take])
                    i += take
                    self.enqueued += take
                    depth = len(self._items)
                    if depth > self.high_watermark:
                        self.high_watermark = depth
                    self._not_empty.notify_all()
            finally:
                if i:  # an admitted prefix counts toward the batch stats
                    self.put_batches += 1

    def _wait_for_room(self, deadline: float) -> None:
        """Caller holds the lock; returns with room available or raises."""
        while len(self._items) >= self.capacity:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise queue.Full
            self._not_full.wait(remaining)
            if self.closed:
                raise ShutDown

    # ---------------------------------------------------------------- gets

    def get(self, timeout: float = 0.2):
        with self._lock:
            if not self._items and not self._wait_for_items(timeout):
                return None
            item = self._items.popleft()
            self.dequeued += 1
            self.get_batches += 1
            self._not_full.notify()
            return item

    def get_many(self, max_items: int = 64, timeout: float = 0.2) -> list:
        """Dequeue up to ``max_items`` under one lock crossing.

        Blocks until at least one item is available; returns ``[]`` on
        timeout or if the queue is closed and empty (never raises — the
        consumer side mirrors ``get``'s None-on-timeout contract).
        """
        with self._lock:
            if not self._items and not self._wait_for_items(timeout):
                return []
            take = min(max_items, len(self._items))
            out = [self._items.popleft() for _ in range(take)]
            self.dequeued += take
            self.get_batches += 1
            self._not_full.notify_all()
            return out

    def _wait_for_items(self, timeout: float) -> bool:
        """Caller holds the lock with the ring empty; True when items
        arrived, False on timeout/close (the deadline clock starts here so
        the non-blocking fast path never reads it)."""
        deadline = time.monotonic() + timeout
        while not self._items:
            if self.closed:
                return False
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            self._not_empty.wait(remaining)
        return True

    def drain(self) -> None:
        with self._lock:
            n = len(self._items)
            self._items.clear()
            self.dequeued += n
            self._not_full.notify_all()

    def take_all(self) -> list:
        """Atomically remove and return everything in the ring (the drain /
        handoff primitive: residual tuples leave as data, not as a drop)."""
        with self._lock:
            items = list(self._items)
            self._items.clear()
            self.dequeued += len(items)
            self._not_full.notify_all()
            return items

    def preload(self, items) -> None:
        """Prepend carried-over residuals ahead of new traffic, ignoring
        capacity (bounded by the producer's ring size, so at worst one ring
        of transient oversubscription).  Used by ``Fabric.publish`` when a
        restarted PE reclaims its predecessor's undelivered input."""
        if not items:
            return
        with self._lock:
            self._items.extendleft(reversed(items))
            self.enqueued += len(items)
            depth = len(self._items)
            if depth > self.high_watermark:
                self.high_watermark = depth
            self._not_empty.notify_all()

    def close(self) -> None:
        """Mark the endpoint dead: pending and future puts raise ``ShutDown``
        (a stale cached sender fails fast instead of feeding a dead ring)."""
        with self._lock:
            self.closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    def stats(self) -> dict:
        depth = len(self._items)
        return {"depth": depth, "capacity": self.capacity,
                "fill": depth / self.capacity if self.capacity else 0.0,
                "enqueued": self.enqueued, "dequeued": self.dequeued,
                "putBatches": self.put_batches, "getBatches": self.get_batches,
                "highWatermark": self.high_watermark,
                "blockedPuts": self.blocked_puts}

    def __len__(self):
        return len(self._items)


# ----------------------------------------------------------- socket backend

_ACK_GRACE = 5.0  # slack past the put timeout before the ack wait gives up


class SocketHub:
    """Receive side of the socket backend: one listener per transport.

    Registered rings are addressed by an opaque token.  Each accepted
    connection gets a handler thread that frames-decodes DATA requests,
    performs the real ring insert (blocking with the request's timeout, so
    backpressure crosses the wire), and replies with an ACK carrying the
    verdict.  A truncated stream (peer died mid-frame) is discarded whole —
    a half-decoded batch never reaches a ring.
    """

    def __init__(self, max_frame: int = DEFAULT_MAX_FRAME):
        self.max_frame = max_frame
        self._lock = threading.Lock()
        self._rings: dict = {}       # token -> TupleQueue
        self._tokens: dict = {}      # id(ring) -> token
        self._token_seq = itertools.count(1)
        self._conns: list = []
        self.closed = False
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(64)
        self.address = self._srv.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="sockhub-accept", daemon=True)
        self._accept_thread.start()

    # ------------------------------------------------------------ registry

    def register(self, ring: TupleQueue) -> str:
        with self._lock:
            token = self._tokens.get(id(ring))
            if token is None:
                token = f"ep{next(self._token_seq)}"
                self._rings[token] = ring
                self._tokens[id(ring)] = token
            return token

    def unregister(self, token: str) -> None:
        with self._lock:
            ring = self._rings.pop(token, None)
            if ring is not None:
                self._tokens.pop(id(ring), None)

    def lookup(self, token: str):
        with self._lock:
            return self._rings.get(token)

    # ---------------------------------------------------------- data plane

    def _accept_loop(self) -> None:
        while not self.closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve, args=(conn,),
                             name="sockhub-conn", daemon=True).start()

    def _serve(self, conn: socket.socket) -> None:
        decoder = FrameDecoder(self.max_frame)
        try:
            while True:
                data = conn.recv(65536)
                if not data:
                    decoder.eof()  # raises on a partial frame: discard it
                    return
                for ftype, payload in decoder.feed(data):
                    if ftype == F_DATA:
                        self._handle_data(conn, payload)
        except (OSError, FrameError):
            # dead/corrupt peer: drop the connection; any partial frame is
            # discarded whole — the sender sees Unreachable, not half a batch
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)

    def _handle_data(self, conn: socket.socket, payload) -> None:
        req_id, token, mode, timeout, items = decode_value(payload)
        ring = self.lookup(token)
        status, admitted, detail = "ok", -1, ""
        if ring is None:
            status = "unknown"  # retired endpoint: sender must fail fast
        else:
            try:
                # unbound base-class insert: the registered ring may be a
                # SocketTupleQueue whose own put IS the socket path — the
                # server side must hit the in-memory ring directly
                if mode == "put":
                    TupleQueue.put(ring, items[0], timeout=timeout)
                else:
                    TupleQueue.put_many(ring, items, timeout=timeout)
            except queue.Full as e:
                status, admitted = "full", getattr(e, "admitted", -1)
            except ShutDown as e:
                status, admitted = "shutdown", getattr(e, "admitted", -1)
            except Exception as e:  # noqa: BLE001 — verdict, not a crash
                status, detail = "error", f"{type(e).__name__}: {e}"
        ack = encode_value((req_id, status, admitted, detail))
        try:
            conn.sendall(encode_frame(F_ACK, ack, self.max_frame))
        except OSError:
            pass  # sender gone; its retry envelope owns recovery

    def close(self) -> None:
        self.closed = True
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = self._conns, []
        for conn in conns:
            try:
                conn.close()
            except OSError:
                pass


class SocketSender:
    """Client half of a socket endpoint: serialize, send, await the ACK.

    One connection per sender, dialed lazily and re-dialed after any
    failure — the *pacing* of reconnect attempts is the caller's retry
    envelope (``EndpointCache`` / runtime flush backoff), which already
    does capped-exponential delays on ``Unreachable``.  Thread-safe; puts
    serialize on the connection lock like they would on a TCP stream.
    """

    def __init__(self, address, token: str,
                 max_frame: int = DEFAULT_MAX_FRAME):
        self.address = tuple(address)
        self.token = token
        self.max_frame = max_frame
        self.closed = False  # sender-handle close (mirror of ring.closed)
        self._lock = threading.RLock()
        self._sock: socket.socket | None = None
        self._decoder = FrameDecoder(max_frame)
        self._req_seq = itertools.count(1)
        self.reconnects = 0

    def _ensure(self) -> socket.socket:
        if self._sock is None:
            try:
                s = socket.create_connection(self.address, timeout=2.0)
            except OSError as e:
                raise Unreachable(
                    f"connect {self.address}: {e}") from None
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
            self._decoder = FrameDecoder(self.max_frame)
            self.reconnects += 1
        return self._sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _request(self, mode: str, items: list, timeout: float):
        """One DATA round-trip; returns on ok, raises the ring's verdict."""
        req_id = next(self._req_seq)
        frame = encode_frame(
            F_DATA,
            encode_value((req_id, self.token, mode, float(timeout), items)),
            self.max_frame)
        with self._lock:
            if self.closed:
                raise ShutDown
            try:
                sock = self._ensure()
                sock.sendall(frame)
                ack = self._await_ack(sock, req_id, timeout + _ACK_GRACE)
            except Unreachable:
                self._drop()
                raise
            except (OSError, FrameError) as e:
                # connection died (or the stream truncated) before the ACK:
                # delivery is unknown, surface the partition-style failure
                self._drop()
                raise Unreachable(
                    f"send to {self.address}/{self.token}: "
                    f"{type(e).__name__}: {e}") from None
        _, status, admitted, detail = ack
        if status == "ok":
            return
        if status == "full":
            err: Exception = queue.Full()
        elif status in ("shutdown", "unknown"):
            # unknown token = the ring was unregistered: same fail-fast
            # contract as a closed ring
            err = ShutDown()
        else:
            err = Unreachable(f"remote put failed: {detail}")
        if admitted >= 0:
            err.admitted = admitted
        raise err

    def _await_ack(self, sock: socket.socket, req_id: int, wait: float):
        deadline = time.monotonic() + wait
        while True:
            for ftype, payload in self._decoder.feed(self._recv(sock, deadline)):
                if ftype != F_ACK:
                    continue
                ack = decode_value(payload)
                if ack[0] == req_id:
                    return ack
                # stale ack from a timed-out predecessor: skip it

    def _recv(self, sock: socket.socket, deadline: float) -> bytes:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise OSError("ack wait timed out")
        sock.settimeout(remaining)
        data = sock.recv(65536)
        if not data:
            raise OSError("connection closed awaiting ack")
        return data

    # TupleQueue-shaped sender surface -----------------------------------

    def put(self, item, timeout: float = 10.0) -> None:
        self._request("put", [item], timeout)

    def put_many(self, items, timeout: float = 10.0) -> None:
        if not isinstance(items, (list, tuple)):
            items = list(items)
        if not items:
            return
        self._request("put_many", list(items), timeout)

    def dispose(self) -> None:
        with self._lock:
            self.closed = True
            self._drop()


class SocketTupleQueue(TupleQueue):
    """A ``TupleQueue`` whose put side crosses a real socket.

    The object *is* the receiving ring (gets/drain/take_all/preload/stats
    are the inherited in-memory operations — consumer semantics untouched),
    but ``put``/``put_many`` loop through the hub over TCP: serialize, one
    ACKed round-trip, and the inherited ring insert happens on the hub's
    connection thread.  Counters, blocking behaviour, ``admitted``
    annotations and exceptions are therefore literally the ring's own —
    the wire only adds the hop.
    """

    def __init__(self, maxsize: int = 1024, hub: SocketHub | None = None):
        super().__init__(maxsize)
        self.hub = hub if hub is not None else _shared_hub()
        self.token = self.hub.register(self)
        self._sender = SocketSender(self.hub.address, self.token,
                                    self.hub.max_frame)

    def put(self, item, timeout: float = 10.0) -> None:
        if self.closed:
            raise ShutDown
        self._sender._request("put", [item], timeout)

    def put_many(self, items, timeout: float = 10.0) -> None:
        if not isinstance(items, (list, tuple)):
            items = list(items)
        if not items:
            return
        if self.closed:
            raise ShutDown
        self._sender._request("put_many", list(items), timeout)

    def close(self) -> None:
        super().close()  # wakes server-side blocked inserts -> acks drain out
        self.hub.unregister(self.token)
        self._sender.dispose()


# ------------------------------------------------------------- the backends

class Transport:
    """Backend seam: how the fabric mints endpoints and probes liveness."""

    name = "inproc"

    def make_queue(self, maxsize: int = 1024) -> TupleQueue:
        return TupleQueue(maxsize)

    def endpoint_alive(self, endpoint) -> bool:
        """Whether a registered endpoint can still accept tuples.  The
        fabric consults this — not thread-local queue state — to classify
        retired vs partitioned peers (a dead remote process must fail fast,
        not retry forever)."""
        return not getattr(endpoint, "closed", False) and \
            not getattr(endpoint, "dead", False)

    def close(self) -> None:
        pass


class InprocTransport(Transport):
    """The seed backend: endpoints are in-process deque rings."""

    name = "inproc"


class SocketTransport(Transport):
    """Endpoints loop tuple batches through a local TCP hub."""

    name = "socket"

    def __init__(self, max_frame: int = DEFAULT_MAX_FRAME):
        self.hub = SocketHub(max_frame)

    def make_queue(self, maxsize: int = 1024) -> SocketTupleQueue:
        return SocketTupleQueue(maxsize, hub=self.hub)

    def close(self) -> None:
        self.hub.close()


_default_lock = threading.Lock()
_default: list = [None]
_shared_hub_box: list = [None]


def _shared_hub() -> SocketHub:
    """Process-wide hub for ``SocketTupleQueue()`` built without an explicit
    transport (the test matrix swaps the queue class in wholesale)."""
    with _default_lock:
        if _shared_hub_box[0] is None or _shared_hub_box[0].closed:
            _shared_hub_box[0] = SocketHub()
        return _shared_hub_box[0]


def default_transport() -> Transport:
    """The backend ``Fabric()`` uses when not given one explicitly."""
    with _default_lock:
        if _default[0] is None:
            _default[0] = InprocTransport()
        return _default[0]


def set_default_transport(transport: Transport | None) -> Transport | None:
    """Swap the process default (the backend-parametrized test fixture);
    returns the previous value so callers can restore it."""
    with _default_lock:
        prev = _default[0]
        _default[0] = transport
        return prev


def make_transport(name: str, **kwargs) -> Transport:
    if name == "inproc":
        return InprocTransport()
    if name == "socket":
        return SocketTransport(**kwargs)
    raise ValueError(f"unknown transport backend {name!r} "
                     "(want 'inproc' or 'socket')")
