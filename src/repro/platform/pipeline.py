"""Job submission pipeline (paper §6.1, steps 1-5).

archive/spec -> logical model -> transforms (parallel expansion, consistent
regions) -> topology model -> fusion into PEs -> per-PE graph metadata.

Everything here is a *pure function* of (job spec, region widths,
generation): the pipeline is re-run — never persisted — for submission,
recovery, and parallel-region width changes (paper §6.3 and lesson §7.1
"don't store what you can compute").  Deterministic hierarchical naming
(PE ids local to job, port ids local to PE) guarantees that re-running at a
new width yields identical metadata for unchanged PEs, which is what lets
the pod conductor restart only the PEs whose ConfigMap actually changed.

Application kinds:
- ``streams``: the paper's own test app (source -> n-way parallel region of
  operator pipelines -> sink) used by the platform benchmarks;
- ``train``:   a data-parallel training job (source -> parallel region of
  trainer shards -> gradient-combine -> sink), the ML workload;
- ``serve``:   a replicated serving job (router -> parallel region of
  server replicas).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class OpDef:
    name: str
    kind: str  # source | pipe | sink | trainer | reducer | server | router
    region: str | None = None
    placement: dict = field(default_factory=dict)
    config: dict = field(default_factory=dict)
    exports: dict | None = None  # {"stream": name, "properties": {...}}
    imports: dict | None = None  # {"subscription": {...}}


@dataclass
class LogicalModel:
    ops: list
    edges: list  # (producer name, consumer name)
    regions: dict  # region name -> default width
    consistent_region: dict | None = None
    hostpools: list = field(default_factory=list)


# ------------------------------------------------------- logical model (1)


def build_logical_model(spec: dict) -> LogicalModel:
    app = spec["app"]
    kind = app.get("type", "streams")
    cr = spec.get("consistentRegion")
    if kind == "streams":
        return _streams_logical(app, cr)
    if kind == "train":
        return _train_logical(app, cr)
    if kind == "serve":
        return _serve_logical(app, cr)
    raise ValueError(f"unknown app type {kind!r}")


def _streams_logical(app: dict, cr) -> LogicalModel:
    width = app.get("width", 2)
    depth = app.get("pipeline_depth", 2)
    ops: list = [OpDef("src", "source", config=app.get("source", {}),
                       exports=app.get("export"))]
    edges = []
    prev = "src"
    for i in range(app.get("pre_ops", 1)):
        ops.append(OpDef(f"pre{i}", "pipe", placement=app.get("placement", {})))
        edges.append((prev, f"pre{i}"))
        prev = f"pre{i}"
    # the parallel region: a pipeline of ``depth`` ops, expanded ``width``-way
    region_first = prev
    rprev = None
    ch_cfg = app.get("channel", {})
    for j in range(depth):
        ops.append(OpDef(f"ch{j}", "pipe", region="par",
                         placement=ch_cfg.get("placement", {}),
                         config=ch_cfg))
        if rprev is None:
            edges.append((region_first, f"ch{j}"))
        else:
            edges.append((rprev, f"ch{j}"))
        rprev = f"ch{j}"
    prev = rprev
    for i in range(app.get("post_ops", 1)):
        ops.append(OpDef(f"post{i}", "pipe"))
        edges.append((prev, f"post{i}"))
        prev = f"post{i}"
    ops.append(OpDef("sink", "sink", imports=app.get("import"),
                     config=app.get("sink", {})))
    edges.append((prev, "sink"))
    return LogicalModel(ops, edges, {"par": width}, cr)


def _train_logical(app: dict, cr) -> LogicalModel:
    width = app.get("data_parallel", 1)
    ops = [
        OpDef("data", "source", config={"role": "data"}),
        OpDef("trainer", "trainer", region="dp", config=app,
              placement=app.get("placement", {})),
        OpDef("combine", "reducer", config=app),
        OpDef("metrics", "sink", exports=app.get("export")),
    ]
    edges = [("data", "trainer"), ("trainer", "combine"), ("combine", "metrics")]
    return LogicalModel(ops, edges, {"dp": width}, cr)


def _serve_logical(app: dict, cr) -> LogicalModel:
    width = app.get("replicas", 1)
    ops = [
        OpDef("router", "router", config=app, imports=app.get("import")),
        OpDef("server", "server", region="replicas", config=app),
        OpDef("responses", "sink", exports=app.get("export")),
    ]
    edges = [("router", "server"), ("server", "responses")]
    return LogicalModel(ops, edges, {"replicas": width}, cr)


# ------------------------------------- transform + topology model (2 & 3)


@dataclass(frozen=True)
class TopoOp:
    id: int  # local to the job — deterministic
    name: str  # e.g. "ch0[2]" for channel replica 2
    logical: str
    kind: str
    region: str | None
    channel: int  # replica index within the region (-1 outside regions)
    placement: dict
    config: dict
    exports: dict | None
    imports: dict | None
    in_region_cr: bool


def expand_topology(model: LogicalModel, widths: dict) -> tuple:
    """Parallel expansion: replicate region ops ``width`` times.

    Returns (topo_ops, topo_edges) with deterministic operator ids: logical
    order first, channel index second — so changing a region's width never
    renumbers operators outside that region's higher channels.
    """
    cr_ops = set()
    if model.consistent_region:
        cr_ops = set(model.consistent_region.get("operators", ())) or {
            op.name for op in model.ops}
    topo: list = []
    name_of: dict = {}  # (logical, channel) -> topo name
    for op in model.ops:
        if op.region is None:
            name_of[(op.name, -1)] = op.name
        else:
            for c in range(widths.get(op.region, model.regions[op.region])):
                name_of[(op.name, c)] = f"{op.name}[{c}]"

    # Deterministic, width-stable ids (paper §7.5): non-region operators
    # first (their ids never move), then region operators ordered by
    # (region, channel, logical position) — growing a region APPENDS ids,
    # so no existing PE is ever renumbered by a width change.
    logical_pos = {op.name: i for i, op in enumerate(model.ops)}
    region_order = {}
    for op in model.ops:
        if op.region is not None and op.region not in region_order:
            region_order[op.region] = len(region_order)
    entries = []  # (sort key, op, channel)
    for op in model.ops:
        if op.region is None:
            entries.append(((0, 0, 0, logical_pos[op.name]), op, -1))
        else:
            w = widths.get(op.region, model.regions[op.region])
            for c in range(w):
                entries.append(((1, region_order[op.region], c,
                                 logical_pos[op.name]), op, c))
    entries.sort(key=lambda e: e[0])
    for idx, (_, op, c) in enumerate(entries):
        topo.append(TopoOp(
            id=idx, name=name_of[(op.name, c)], logical=op.name,
            kind=op.kind, region=op.region, channel=c,
            placement=op.placement, config=op.config,
            exports=op.exports, imports=op.imports,
            in_region_cr=op.name in cr_ops))

    by_logical: dict = {}
    for t in topo:
        by_logical.setdefault(t.logical, []).append(t)

    edges: list = []
    logical_region = {op.name: op.region for op in model.ops}
    for a, b in model.edges:
        ra, rb = logical_region[a], logical_region[b]
        if ra is None and rb is None:
            edges.append((by_logical[a][0].name, by_logical[b][0].name))
        elif ra is None and rb is not None:
            for t in by_logical[b]:  # split: producer feeds every channel
                edges.append((by_logical[a][0].name, t.name))
        elif ra is not None and rb is None:
            for t in by_logical[a]:  # merge: every channel feeds consumer
                edges.append((t.name, by_logical[b][0].name))
        elif ra == rb:
            for ta, tb in zip(by_logical[a], by_logical[b]):
                if ta.channel == tb.channel:
                    edges.append((ta.name, tb.name))
        else:  # cross-region: full mesh
            for ta in by_logical[a]:
                for tb in by_logical[b]:
                    edges.append((ta.name, tb.name))
    return topo, edges


# ------------------------------------------------------------- fusion (4)


@dataclass
class PEPlan:
    pe_id: int
    operators: list  # list[TopoOp]
    input_ports: list  # [{"portId", "from": [peId, portId], "operator"}]
    output_ports: list  # [{"portId", "to": [[peId, portId], ...], "operator"}]
    pod_spec: dict = field(default_factory=dict)

    @property
    def graph_metadata(self) -> dict:
        return {
            "peId": self.pe_id,
            "operators": [
                {"id": o.id, "name": o.name, "kind": o.kind,
                 "channel": o.channel, "region": o.region,
                 "config": o.config, "inCR": o.in_region_cr}
                for o in self.operators
            ],
            "inputs": self.input_ports,
            "outputs": self.output_ports,
        }


def fuse(topo: list, edges: list, scheme: str = "one-per-op") -> list:
    """Fusion into PEs.  ``one-per-op`` (paper's experiments) or
    ``per-channel`` (each parallel channel's pipeline fused into one PE)."""
    groups: list = []
    if scheme == "per-channel":
        seen: dict = {}
        for t in topo:
            key = ("ch", t.region, t.channel) if t.region else ("op", t.name)
            if key not in seen:
                seen[key] = []
                groups.append(seen[key])
            seen[key].append(t)
    else:
        groups = [[t] for t in topo]

    # deterministic PE ids: order of first operator id
    groups.sort(key=lambda g: g[0].id)
    plans = [PEPlan(pe_id=i, operators=g, input_ports=[], output_ports=[])
             for i, g in enumerate(groups)]
    pe_of_op = {}
    for p in plans:
        for o in p.operators:
            pe_of_op[o.name] = p

    # ports: deterministic local ids in edge-sorted order (paper §6.3)
    name_to_op = {t.name: t for t in topo}
    cross = [(a, b) for a, b in sorted(edges)
             if pe_of_op[a].pe_id != pe_of_op[b].pe_id]
    out_port_id: dict = {}
    in_port_id: dict = {}
    for a, b in cross:
        pa, pb = pe_of_op[a], pe_of_op[b]
        if (pa.pe_id, a) not in out_port_id:
            out_port_id[(pa.pe_id, a)] = len(pa.output_ports)
            pa.output_ports.append({"portId": len(pa.output_ports),
                                    "operator": a, "to": []})
        if (pb.pe_id, b) not in in_port_id:
            in_port_id[(pb.pe_id, b)] = len(pb.input_ports)
            pb.input_ports.append({"portId": len(pb.input_ports),
                                   "operator": b, "from": []})
        po = out_port_id[(pa.pe_id, a)]
        pi = in_port_id[(pb.pe_id, b)]
        pa.output_ports[po]["to"].append([pb.pe_id, pi])
        pb.input_ports[pi]["from"].append([pa.pe_id, po])
    return plans


# ----------------------------------------------- scheduling constraints (6)

#: Default requested cores per operator kind — what a pod asks the
#: scheduler's capacity filter / spread scorer for when no explicit
#: ``placement.cores`` is given.  Heavy compute kinds (trainer shards,
#: serving replicas) request a full core; streaming pipes half; plumbing
#: operators a quarter.
KIND_CORES = {"trainer": 1.0, "server": 1.0, "pipe": 0.5, "reducer": 0.5,
              "source": 0.25, "sink": 0.25, "router": 0.25}


def pod_specs(plans: list, job: str) -> None:
    """Fill each plan's pod_spec from SPL placement semantics (paper §6.2).

    colocate  -> podAffinity on a shared label
    exlocate  -> podAntiAffinity on a shared label (symmetric+transitive)
    isolate   -> unique label on every *other* pod + podAntiAffinity here
                 (builds symmetric isolation from the asymmetric primitive)
    host      -> nodeName;  hostpool tags -> nodeAffinity
    cores     -> resources request ({"cores": float}; defaults summed from
                 ``KIND_CORES`` over the PE's fused operators)
    """
    iso_tokens = []
    for p in plans:
        for o in p.operators:
            if o.placement.get("isolate"):
                iso_tokens.append((p.pe_id, f"iso-{job}-pe-{p.pe_id}"))
    for p in plans:
        labels: dict = {}
        affinity: list = []
        anti: list = []
        node_name = None
        node_tags: list = []
        cores = 0.0
        for o in p.operators:
            pl = o.placement
            cores += float(pl.get("cores", KIND_CORES.get(o.kind, 0.5)))
            if pl.get("colocate"):
                labels[f"colo-{pl['colocate']}"] = "1"
                affinity.append(f"colo-{pl['colocate']}")
            if pl.get("exlocate"):
                labels[f"exlo-{pl['exlocate']}"] = "1"
                anti.append(f"exlo-{pl['exlocate']}")
            if pl.get("host"):
                node_name = pl["host"]
            if pl.get("hostpool_tags"):
                node_tags.extend(pl["hostpool_tags"])
        for pe_id, token in iso_tokens:
            if pe_id == p.pe_id:
                anti.append(token)  # the requester anti-affines to the label
            else:
                labels[token] = "1"  # everyone else carries the label
        p.pod_spec = {
            "labels": labels,
            "podAffinity": affinity,
            "podAntiAffinity": anti,
            "nodeName": node_name,
            "nodeAffinityTags": node_tags,
            "resources": {"cores": cores},
        }


# -------------------------------------------------------------- full plan


@dataclass
class JobPlan:
    job: str
    generation: int
    widths: dict
    pes: list  # list[PEPlan]
    exports: list  # (op name, stream, properties)
    imports: list  # (op name, subscription)
    consistent_region: dict | None
    logical: LogicalModel


def drain_handoff(plan: JobPlan, meta: dict) -> dict:
    """Handoff targets for a retiring PE, computed from the *new* generation.

    Pure function of (new plan, retiring PE's graph metadata) — the pr
    coordinator's width edit re-ran the pipeline, and the surviving sibling
    of a retired channel is fully determined by it: the same logical
    operator at channel ``c % new_width``.  Returns ``{"siblings": [[pe,
    port], ...]}`` — the surviving input endpoints a draining PE hands
    residual tuples to when its ``drain_timeout`` expires before it can
    process them itself.  Empty when the retiring operator is outside any
    region (nothing to hand off to) or the region collapsed to width 0.

    The result rides in the pod's drain request, next to the ``downstream``
    closure the operator uses for delivery-path holds: together they are
    what the ``streams/drain`` finalizer promises to resolve before the
    retiring resources may be reaped (see ``operator.py``).
    """
    op0 = (meta.get("operators") or [{}])[0]
    region = op0.get("region")
    name = op0.get("name", "")
    if not region or "[" not in name:
        return {"siblings": []}
    logical = name.split("[", 1)[0]
    channel = op0.get("channel", 0)
    width = plan.widths.get(region, 0)
    if width <= 0:
        return {"siblings": []}
    sibling = f"{logical}[{channel % width}]"
    for pe in plan.pes:
        for port in pe.input_ports:
            if port["operator"] == sibling:
                return {"siblings": [[pe.pe_id, port["portId"]]]}
    return {"siblings": []}


def plan_job(job: str, spec: dict, widths: dict | None = None,
             generation: int = 1) -> JobPlan:
    """The full pipeline: spec -> PE plans + metadata.  Pure & deterministic."""
    model = build_logical_model(spec)
    widths = {**model.regions, **(widths or {})}
    topo, edges = expand_topology(model, widths)
    plans = fuse(topo, edges, spec.get("fusion", "one-per-op"))
    pod_specs(plans, job)
    exports = [(t.name, t.exports["stream"], t.exports.get("properties", {}))
               for t in topo if t.exports]
    imports = [(t.name, t.imports["subscription"]) for t in topo if t.imports]
    return JobPlan(job, generation, widths, plans, exports, imports,
                   model.consistent_region, model)
