"""Cluster substrate: nodes, kubelets, and the node pressure plane.

This is the "Kubernetes" half of the system (the part the paper *offloads
to*): kubelet controllers that start/stop the PE runtime for pods bound to
their node, and the node pressure plane — a kubelet-side heartbeat that
publishes per-node oversubscription signals (pods-per-core, aggregate ring
fill of hosted PEs, straggler heartbeat lag) as Node status conditions
through the declarative API.  The *scheduler* (filter/score plugin
pipeline consuming those conditions) lives in ``scheduler.py``; pod
creation and failure handling belong to the platform (instance operator) —
exactly the paper's division of responsibility.

The kubelet optionally models CPU oversubscription (``cpu_model=True``):
when a node hosts more running PEs than spec cores, every hosted runtime's
synthetic per-tuple work is stretched by the inverse share — the §8
pathology ("Kubernetes has problems with oversubscription") made
measurable, which is what the ``oversub`` benchmark compares schedulers
against.
"""

from __future__ import annotations

import threading
import time

from ..core import Controller, Coordinator, Resource, ResourceStore, \
    condition_is, set_condition
from . import crds
from .api import ensure_api
from .fabric import Fabric
from .prochost import HostBridge
from .runtime import PERuntime
from .scheduler import NodeController, SchedulerController  # noqa: F401 — the
#   scheduler moved to scheduler.py; re-exported for substrate callers
from .tracing import drain_token, pod_token, span_tracer


class PodHandle:
    def __init__(self, runtime: PERuntime, stop_event: threading.Event,
                 node: str | None = None):
        self.runtime = runtime
        self.stop_event = stop_event
        self.node = node

    def stop(self, timeout: float = 5.0) -> None:
        self.stop_event.set()
        self.runtime.join(timeout=timeout)

    def kill(self) -> bool:
        self.stop(timeout=5.0)
        return True


class _RemoteRuntime:
    """The slice of the ``PERuntime`` surface the kubelet touches, proxied
    to a worker-hosted runtime over the control channel."""

    def __init__(self, client, pod_name: str, job: str, pe_id: int):
        self.client = client
        self.pod_name = pod_name
        self.job = job
        self.pe_id = pe_id
        self.draining = False

    def is_alive(self) -> bool:
        return self.client.alive and self.pod_name in self.client.pods

    def begin_drain(self, req: dict) -> None:
        self.client.begin_drain(self.pod_name, req)
        self.draining = True

    def drain_upstream_gone(self, pe_id: int) -> None:
        self.client.drain_upstream_gone(self.job, pe_id)

    def join(self, timeout: float | None = None) -> None:
        pass  # lifecycle is RPC-driven; exits arrive as pod_exit casts


class RemotePodHandle:
    """Kubelet-side handle for a pod hosted in a node's worker process."""

    def __init__(self, client, pod_name: str, job: str, pe_id: int,
                 node: str | None):
        self.client = client
        self.pod_name = pod_name
        self.node = node
        self.runtime = _RemoteRuntime(client, pod_name, job, pe_id)

    def stop(self, timeout: float = 5.0) -> None:
        try:
            self.client.stop_pod(self.pod_name, timeout)
        except Exception:  # noqa: BLE001 — worker death has its own path
            pass

    def kill(self) -> bool:
        try:
            return self.client.kill_pod(self.pod_name)
        except Exception:  # noqa: BLE001 — dead worker: pod fails anyway
            return False


class KubeletController(Controller):
    """Starts/stops PE runtimes for pods bound to nodes (all nodes in one
    controller here — the per-node split is an artifact of real clusters).

    With ``cpu_model=True`` the kubelet also models node CPU contention:
    each node's running PEs share ``spec.cores`` equally, and every hosted
    runtime stretches its synthetic per-tuple work by the inverse share
    (see ``PERuntime``'s ``cpu_share`` hook) — oversubscribing a node
    measurably slows every PE on it.

    ``start_delay`` models container boot (image pull + process start —
    the seconds a real kubelet pays before a pod's runtime is live): every
    freshly started runtime sleeps it before entering the data plane.  A
    warm standby pays it at *standby creation*, off the critical path, so
    promotion skips exactly this cost — which is the recovery plane's whole
    argument.  Defaults to 0.0 (no modeled boot)."""

    def __init__(self, store: ResourceStore, pod_coord: Coordinator,
                 fabric: Fabric, rest, namespace=None, trace=None,
                 cpu_model: bool = False, start_delay: float = 0.0):
        super().__init__(store, crds.POD, namespace, "kubelet", trace)
        self.pod_coord = pod_coord
        self.fabric = fabric
        self.rest = rest
        self.cpu_model = cpu_model
        self.start_delay = float(start_delay)
        self.handles: dict = {}
        self._hlock = threading.Lock()
        self._shares: dict = {}  # node -> cpu share in (0, 1]; lock-free reads
        # start-pod retry envelope: a failed start (transient fabric/config
        # trouble under chaos) backs off with a capped exponential delay
        # instead of either crashing the kubelet thread or retrying hot on
        # every pod event; the next event after the deadline re-attempts
        self._start_backoff: dict = {}  # pod name -> (attempt, retry_at)
        self.start_retries = 0
        # cross-process hosting: nodes with spec.processIsolation get their
        # PEs in a per-node worker process behind a HostBridge (lazy: pure
        # in-process clusters never open a socket)
        self._bridge: HostBridge | None = None
        self._block = threading.Lock()

    def bridge(self) -> HostBridge:
        with self._block:
            if self._bridge is None:
                self._bridge = HostBridge(
                    self.fabric, self.rest,
                    on_pod_exit=self._on_remote_exit,
                    on_worker_lost=self._on_worker_lost)
            return self._bridge

    def _node_isolated(self, node: str | None) -> bool:
        if not node:
            return False
        res = self.store.try_get(crds.NODE, node)
        return bool(res is not None and res.spec.get("processIsolation"))

    def cpu_share(self, node: str | None) -> float:
        """Current CPU share of one PE on ``node`` (1.0 without the model)."""
        if not self.cpu_model or node is None:
            return 1.0
        return self._shares.get(node, 1.0)

    def _recompute_shares(self) -> None:
        """Caller holds ``_hlock``.  share(node) = cores / running PEs,
        capped at 1 — the equal-slice contention model."""
        if not self.cpu_model:
            return
        counts: dict = {}
        for handle in self.handles.values():
            if handle.node:
                counts[handle.node] = counts.get(handle.node, 0) + 1
        shares: dict = {}
        for node_name, n in counts.items():
            node = self.store.try_get(crds.NODE, node_name)
            cores = node.spec.get("cores", 8) if node is not None else 8
            shares[node_name] = min(1.0, cores / max(n, 1))
        self._shares = shares  # atomic swap: runtimes read without the lock

    def on_addition(self, res: Resource) -> None:
        self._maybe_start(res)

    def on_modification(self, old, new) -> None:
        if new.status.get("draining") and not (
                old is not None and old.status.get("draining")):
            self._begin_drain(new)
        self._maybe_start(new)

    def on_deletion(self, res: Resource) -> None:
        if not res.spec.get("standby"):
            pe = self.store.try_get(crds.PE,
                                    crds.pe_name(res.spec["job"],
                                                 res.spec["peId"]),
                                    res.namespace)
            if pe is not None and condition_is(pe, crds.COND_PROMOTING):
                # mid-promotion record churn: the adopted standby handle
                # already owns this pod name — stopping it here would kill
                # the runtime the failover conductor just swapped in
                return
        self.stop_pod(res.name)
        # permanent death vs restart: with no live PE left to bump a
        # launchCount, this pod will never republish — any drain gated on
        # its restart must stop waiting (its final flush already landed:
        # stop_pod joined the runtime above).  Restart-deletes keep the
        # gate: their PE survives and the new incarnation will publish.
        pe = self.store.try_get(crds.PE,
                                crds.pe_name(res.spec["job"],
                                             res.spec["peId"]),
                                res.namespace)
        if pe is None or pe.terminating:
            with self._hlock:
                handles = list(self.handles.values())
            for handle in handles:
                rt = handle.runtime
                if rt.job == res.spec["job"] and rt.draining:
                    rt.drain_upstream_gone(res.spec["peId"])

    def _begin_drain(self, pod: Resource) -> None:
        """Forward a scale-down drain request to the PE runtime: mark the
        fabric endpoints drain-only (no new producers resolve to them; all
        sender caches invalidate on the epoch bump) and hand the runtime
        the drain parameters + handoff targets."""
        with self._hlock:
            handle = self.handles.get(pod.name)
        sp = span_tracer(self.trace)
        parent = sp.context(drain_token(pod.name)) if sp is not None else None
        if handle is None or not handle.runtime.is_alive():
            # nothing running here (already exited): report an empty drain
            # so the pod conductor finalizes the retirement
            if sp is not None:
                sp.end_span(sp.start_span(self.name, "begin-drain", pod.key,
                                          parent=parent, empty=True))
            self.pod_coord.submit_status(
                pod.name, {"drained": {"tuplesDropped": 0, "handedOff": 0,
                                       "drainMs": 0.0, "clean": True}},
                requester=self.name)
            return
        if sp is None:
            self.fabric.set_draining(pod.spec["job"], pod.spec["peId"])
            handle.runtime.begin_drain(pod.status["draining"])
        else:
            with sp.span(self.name, "begin-drain", pod.key, parent=parent):
                self.fabric.set_draining(pod.spec["job"], pod.spec["peId"])
                handle.runtime.begin_drain(pod.status["draining"])

    def _maybe_start(self, pod: Resource) -> None:
        if not pod.spec.get("nodeName") or pod.status.get("phase") != "Pending" \
                or pod.terminating:
            return
        backoff = self._start_backoff.get(pod.name)
        if backoff is not None and time.monotonic() < backoff[1]:
            return  # inside the retry envelope: wait for the deadline
        try:
            node = pod.spec.get("nodeName")
            # isolated node: spawn/reuse the node's worker process first
            # (outside _hlock — a first spawn pays the interpreter start)
            client = self.bridge().ensure_worker(node) \
                if self._node_isolated(node) else None
            with self._hlock:
                if pod.name in self.handles:
                    return
                cm = self.store.try_get(crds.CONFIG_MAP,
                                        crds.cm_name(pod.spec["job"], pod.spec["peId"]),
                                        pod.namespace)
                if cm is None:  # pod conductor guarantees this; guard anyway
                    return
                standby = bool(pod.spec.get("standby"))
                metadata = cm.spec["data"]
                if standby:
                    metadata = {**metadata,
                                "standbyWarmInterval":
                                    pod.spec.get("warmInterval", 0.5)}
                if self.start_delay:
                    metadata = {**metadata, "startDelay": self.start_delay}
                if client is not None:
                    runtime = None
                    handle = RemotePodHandle(client, pod.name,
                                             pod.spec["job"],
                                             pod.spec["peId"], node)
                else:
                    stop = threading.Event()
                    runtime = PERuntime(
                        job=pod.spec["job"], pe_id=pod.spec["peId"],
                        metadata=metadata, fabric=self.fabric, rest=self.rest,
                        launch_count=pod.spec.get("launchCount", 0), stop_event=stop,
                        on_exit=self._on_runtime_exit,
                        cpu_share=(lambda n=node: self.cpu_share(n)),
                        standby=standby,
                        pod_name=pod.name if standby else None)
                    handle = PodHandle(runtime, stop, node)
                self.handles[pod.name] = handle
                self._recompute_shares()
            if client is not None:
                try:
                    client.start_pod(pod.name, pod.spec["job"],
                                     pod.spec["peId"], metadata,
                                     pod.spec.get("launchCount", 0),
                                     standby=standby)
                except Exception:
                    with self._hlock:
                        self.handles.pop(pod.name, None)
                        self._recompute_shares()
                    raise
        except Exception:  # noqa: BLE001 — transient start failure: back off
            attempt = backoff[0] + 1 if backoff is not None else 1
            delay = min(0.1 * (2 ** (attempt - 1)), 2.0)
            self._start_backoff[pod.name] = (attempt, time.monotonic() + delay)
            self.start_retries += 1
            self._record("start-pod-backoff", pod.key, f"attempt={attempt}")
            return
        self._start_backoff.pop(pod.name, None)
        sp = span_tracer(self.trace)
        if sp is not None:
            with sp.span(self.name, "start-pod", pod.key,
                         parent=sp.context(pod_token(pod.name)),
                         node=node, launch=pod.spec.get("launchCount", 0),
                         isolated=client is not None):
                self.pod_coord.submit_status(pod.name, {"phase": "Running"},
                                             requester=self.name)
                if runtime is not None:
                    runtime.start()
            return
        self.pod_coord.submit_status(pod.name, {"phase": "Running"},
                                     requester=self.name)
        if runtime is not None:
            runtime.start()

    def _on_runtime_exit(self, runtime: PERuntime) -> None:
        # a holding standby reports under its own pod name; a promoted one
        # has cleared the override and reports as the primary
        pod_name = (runtime.pod_name_override
                    or crds.pod_name(runtime.job, runtime.pe_id))
        with self._hlock:
            self.handles.pop(pod_name, None)
            self._recompute_shares()
        if runtime.crashed:
            self.pod_coord.submit_status(pod_name, {"phase": "Failed"},
                                         requester=self.name)
        elif runtime.drain_stats is not None:
            # drained: the pod conductor finalizes the retirement on this
            self.pod_coord.submit_status(
                pod_name, {"phase": "Succeeded",
                           "drained": runtime.drain_stats},
                requester=self.name)
        elif not runtime.stop_event.is_set():
            self.pod_coord.submit_status(pod_name, {"phase": "Succeeded"},
                                         requester=self.name)

    def _on_remote_exit(self, pod_name: str, crashed: bool,
                        drain_stats: dict | None, stopped: bool) -> None:
        """A worker-hosted runtime exited (pod_exit cast from the bridge) —
        mirror ``_on_runtime_exit`` verbatim across the process boundary."""
        with self._hlock:
            self.handles.pop(pod_name, None)
            self._recompute_shares()
        if crashed:
            self.pod_coord.submit_status(pod_name, {"phase": "Failed"},
                                         requester=self.name)
        elif drain_stats is not None:
            self.pod_coord.submit_status(
                pod_name, {"phase": "Succeeded", "drained": drain_stats},
                requester=self.name)
        elif not stopped:
            self.pod_coord.submit_status(pod_name, {"phase": "Succeeded"},
                                         requester=self.name)

    def _on_worker_lost(self, node: str, pods: list) -> None:
        """A worker process died under its pods: every one of them is gone
        with it.  The bridge already retired their endpoints (epoch bump +
        dead flags); failing the pods here hands recovery to the normal
        restart chain, which respawns the worker on the next start."""
        with self._hlock:
            for name in pods:
                self.handles.pop(name, None)
            self._recompute_shares()
        for name in pods:
            self.pod_coord.submit_status(name, {"phase": "Failed"},
                                         requester=self.name)
        self._record("worker-lost", node, f"pods={len(pods)}")

    def stop_pod(self, pod_name: str, timeout: float = 5.0) -> None:
        with self._hlock:
            handle = self.handles.pop(pod_name, None)
            self._recompute_shares()
        if handle:
            handle.stop(timeout=timeout)

    def kill_pod(self, pod_name: str) -> bool:
        """Simulate an involuntary PE crash (test/benchmark hook)."""
        with self._hlock:
            handle = self.handles.pop(pod_name, None)
            self._recompute_shares()
        if not handle:
            return False
        handle.kill()
        sp = span_tracer(self.trace)
        if sp is not None:
            # the recovery clock starts at the failure injection: the span
            # stays open through restart-chain links (recover/bind/start,
            # parented here via the pod token) until the replacement
            # runtime reports connected.  Killing a holding standby is not
            # a service interruption — no recover span for those
            pod = self.store.try_get(crds.POD, pod_name)
            if pod is not None and not pod.spec.get("standby") \
                    and sp.context(pod_token(pod_name)) is None:
                sp.attach(pod_token(pod_name),
                          sp.start_span("chaos", "recover", pod.key,
                                        job=handle.runtime.job,
                                        pe=handle.runtime.pe_id,
                                        cause="kill"))
        self.pod_coord.submit_status(pod_name, {"phase": "Failed"},
                                     requester="chaos")
        return True

    # ---------------------------------------------------- standby promotion

    def adopt_standby(self, standby_name: str, primary_name: str):
        """Re-key a live standby handle under the primary pod name (failover
        conductor, step 1 of a promotion).  Done BEFORE the replacement pod
        record exists: ``_maybe_start``'s handles guard then blocks any
        duplicate runtime for the primary name.  Returns the node name, or
        None when there is no live standby to adopt (degraded: fall back to
        the cold restart chain)."""
        with self._hlock:
            handle = self.handles.get(standby_name)
            if handle is None or primary_name in self.handles:
                return None
            if isinstance(handle, PodHandle) and not handle.runtime.is_alive():
                return None
            del self.handles[standby_name]
            self.handles[primary_name] = handle
            if isinstance(handle, RemotePodHandle):
                handle.pod_name = primary_name
                handle.runtime.pod_name = primary_name
        self._record("adopt-standby", primary_name, f"from={standby_name}")
        return handle.node

    def signal_promote(self, standby_name: str, primary_name: str,
                       launch_count: int) -> bool:
        """Step 2 of a promotion (after the pod records converged): wake the
        adopted runtime out of its hold — it publishes its input rings (one
        epoch bump; the fabric's residual carryover preloads the dead
        primary's undelivered tuples) and reports connected, which closes
        the recover span."""
        with self._hlock:
            handle = self.handles.get(primary_name)
        if handle is None:
            return False
        if isinstance(handle, RemotePodHandle):
            try:
                handle.client.promote_pod(standby_name, primary_name,
                                          launch_count)
            except Exception:  # noqa: BLE001 — dead worker: degraded path
                return False
        else:
            handle.runtime.promote(launch_count)
        self._record("promote-standby", primary_name,
                     f"launch={launch_count}")
        return True

    def stop_all(self) -> None:
        with self._hlock:
            names = list(self.handles)
        for n in names:
            self.stop_pod(n)
        with self._block:
            bridge, self._bridge = self._bridge, None
        if bridge is not None:
            bridge.shutdown()


class NodePressureMonitor:
    """The kubelets' per-node pressure heartbeat (ROADMAP's per-node
    oversubscription signals).

    Every ``interval`` seconds (or on an explicit ``report()`` — tests and
    deterministic runs call it directly) it aggregates, per node, over the
    RUNNING pods bound there:

    - ``podsPerCore``:   running pods / spec cores — the oversubscription
                         ratio proper;
    - ``ringFill``:      mean input-ring backpressure of the hosted PEs
                         (from the load samples they already report);
    - ``heartbeatLag``:  max staleness of the hosted pods' heartbeats —
                         the node-level straggler signal;

    and writes them as ``status.pressure`` plus the ``Pressure`` /
    ``Straggling`` conditions on the Node resource, through the declarative
    API (the PR-4 rule: conditions are the platform's only signal surface).
    The ``Pressure`` condition keys on podsPerCore alone (a saturated ring
    on an idle node is an app problem, not a node problem); the blended
    ``score`` (pods-per-core and ring fill) rides in status for the
    scheduler's pressure-avoidance scorer to rank by.
    """

    def __init__(self, store: ResourceStore, namespace, coords=None,
                 trace=None, *, api=None, interval: float = 0.5,
                 pods_per_core_hot: float = 1.0, fill_weight: float = 0.5,
                 straggle_after: float = 5.0, clock=time.time):
        self.store = store
        self.namespace = namespace
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.trace = trace
        self.interval = interval
        self.pods_per_core_hot = pods_per_core_hot
        self.fill_weight = fill_weight
        self.straggle_after = straggle_after
        self.clock = clock
        self._stop = threading.Event()
        self._thread = None

    # ------------------------------------------------------------- sampling

    def snapshot(self, now: float | None = None) -> dict:
        """Pure aggregation: node name -> pressure sample dict."""
        now = self.clock() if now is None else now
        per_node: dict = {}
        for pod in self.store.list(crds.POD, self.namespace):
            node = pod.spec.get("nodeName")
            if not node or pod.status.get("phase") != "Running":
                continue
            entry = per_node.setdefault(node, {"pods": 0, "fills": [],
                                               "lag": 0.0})
            entry["pods"] += 1
            metrics = pod.status.get("metrics") or {}
            if "backpressure" in metrics:
                entry["fills"].append(metrics["backpressure"])
            hb = pod.status.get("heartbeat")
            if hb is not None:
                entry["lag"] = max(entry["lag"], now - hb)
        out: dict = {}
        for node in self.store.list(kind=crds.NODE):
            entry = per_node.get(node.name, {"pods": 0, "fills": [], "lag": 0.0})
            cores = max(node.spec.get("cores", 8), 1e-9)
            ppc = entry["pods"] / cores
            fill = (sum(entry["fills"]) / len(entry["fills"])
                    if entry["fills"] else 0.0)
            out[node.name] = {
                "pods": entry["pods"],
                "podsPerCore": round(ppc, 4),
                "ringFill": round(fill, 4),
                "heartbeatLag": round(entry["lag"], 3),
                # the scorer's ranking signal: oversubscription, nudged by
                # how loaded the hosted rings actually are
                "score": round(ppc / self.pods_per_core_hot
                               + self.fill_weight * fill, 4),
            }
        return out

    # ------------------------------------------------------------ reporting

    def report(self, now: float | None = None) -> dict:
        """One heartbeat: write every node's pressure sample + conditions."""
        now = self.clock() if now is None else now
        samples = self.snapshot(now)
        for node_name, sample in samples.items():
            hot = sample["podsPerCore"] >= self.pods_per_core_hot
            straggling = sample["heartbeatLag"] > self.straggle_after

            def write(res: Resource, sample=sample, hot=hot,
                      straggling=straggling) -> None:
                res.status["pressure"] = {**sample, "updatedAt": now}
                set_condition(res, crds.COND_PRESSURE,
                              "True" if hot else "False",
                              reason="Oversubscribed" if hot else "InBudget",
                              message=f"podsPerCore={sample['podsPerCore']}")
                set_condition(res, crds.COND_STRAGGLING,
                              "True" if straggling else "False",
                              reason="StaleHeartbeat" if straggling
                              else "Fresh",
                              message=f"lag={sample['heartbeatLag']}s")

            self.api.nodes.edit(node_name, write, requester="pressure-monitor")
        return samples

    # --------------------------------------------------------------- daemon

    def start(self, interval: float | None = None) -> None:
        interval = self.interval if interval is None else interval

        def loop():
            while not self._stop.is_set():
                try:
                    self.report()
                except Exception:  # noqa: BLE001 — heartbeat must not die
                    pass
                self._stop.wait(interval)

        self._thread = threading.Thread(target=loop, name="pressure-monitor",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
