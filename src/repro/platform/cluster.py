"""Cluster substrate: nodes, scheduler, kubelets.

This is the "Kubernetes" half of the system (the part the paper *offloads
to*): a scheduler controller that assigns pods to nodes honoring
affinity/anti-affinity/nodeName constraints and balancing load, and kubelet
controllers that start/stop the PE runtime for pods bound to their node.
Pod *creation* and failure *handling* belong to the platform (instance
operator), not here — exactly the paper's division of responsibility.
"""

from __future__ import annotations

import threading

from ..core import Controller, Coordinator, Resource, ResourceStore
from . import crds
from .fabric import Fabric
from .runtime import PERuntime


class SchedulerController(Controller):
    """Assigns ``nodeName`` to pending pods (paper §6.2 semantics)."""

    def __init__(self, store: ResourceStore, pod_coord: Coordinator,
                 namespace=None, trace=None):
        super().__init__(store, crds.POD, namespace, "scheduler", trace)
        self.pod_coord = pod_coord

    def on_addition(self, res: Resource) -> None:
        self._maybe_schedule(res)

    def on_modification(self, old, new) -> None:
        if not new.spec.get("nodeName") and new.status.get("phase") == "Pending":
            self._maybe_schedule(new)

    def _maybe_schedule(self, pod: Resource) -> None:
        if pod.spec.get("nodeName") or pod.terminating:
            return
        nodes = self.store.list(kind=crds.NODE)
        if not nodes:
            return
        placed = [p for p in self.cache.values()
                  if p.kind == crds.POD and p.spec.get("nodeName")]
        by_node: dict = {}
        for p in placed:
            by_node.setdefault(p.spec["nodeName"], []).append(p)

        want = pod.spec.get("pod_spec", {})
        affinity = want.get("podAffinity", [])
        anti = want.get("podAntiAffinity", [])
        tags = set(want.get("nodeAffinityTags", []))
        forced = want.get("nodeName")

        def pod_labels(p):
            return p.spec.get("pod_spec", {}).get("labels", {})

        candidates = []
        for node in nodes:
            if forced and node.name != forced:
                continue
            if tags and not tags.issubset(set(node.labels)):
                continue
            here = by_node.get(node.name, [])
            if any(lbl in pod_labels(p) for p in here for lbl in anti):
                continue
            if affinity:
                anywhere = [p for p in placed
                            if any(lbl in pod_labels(p) for lbl in affinity)]
                if anywhere and not any(p.spec["nodeName"] == node.name
                                        for p in anywhere):
                    continue
            load = len(here) / max(node.spec.get("cores", 8), 1)
            candidates.append((load, node.name))
        if not candidates:
            self.pod_coord.submit_status(pod.name, {"phase": "Unschedulable"},
                                         requester=self.name)
            return
        candidates.sort()
        node_name = candidates[0][1]

        def bind(res: Resource) -> None:
            res.spec["nodeName"] = node_name

        self.pod_coord.submit(pod.name, bind, requester=self.name)


class PodHandle:
    def __init__(self, runtime: PERuntime, stop_event: threading.Event):
        self.runtime = runtime
        self.stop_event = stop_event


class KubeletController(Controller):
    """Starts/stops PE runtimes for pods bound to nodes (all nodes in one
    controller here — the per-node split is an artifact of real clusters)."""

    def __init__(self, store: ResourceStore, pod_coord: Coordinator,
                 fabric: Fabric, rest, namespace=None, trace=None):
        super().__init__(store, crds.POD, namespace, "kubelet", trace)
        self.pod_coord = pod_coord
        self.fabric = fabric
        self.rest = rest
        self.handles: dict = {}
        self._hlock = threading.Lock()

    def on_addition(self, res: Resource) -> None:
        self._maybe_start(res)

    def on_modification(self, old, new) -> None:
        if new.status.get("draining") and not (
                old is not None and old.status.get("draining")):
            self._begin_drain(new)
        self._maybe_start(new)

    def on_deletion(self, res: Resource) -> None:
        self.stop_pod(res.name)
        # permanent death vs restart: with no live PE left to bump a
        # launchCount, this pod will never republish — any drain gated on
        # its restart must stop waiting (its final flush already landed:
        # stop_pod joined the runtime above).  Restart-deletes keep the
        # gate: their PE survives and the new incarnation will publish.
        pe = self.store.try_get(crds.PE,
                                crds.pe_name(res.spec["job"],
                                             res.spec["peId"]),
                                res.namespace)
        if pe is None or pe.terminating:
            with self._hlock:
                handles = list(self.handles.values())
            for handle in handles:
                rt = handle.runtime
                if rt.job == res.spec["job"] and rt.draining:
                    rt.drain_upstream_gone(res.spec["peId"])

    def _begin_drain(self, pod: Resource) -> None:
        """Forward a scale-down drain request to the PE runtime: mark the
        fabric endpoints drain-only (no new producers resolve to them; all
        sender caches invalidate on the epoch bump) and hand the runtime
        the drain parameters + handoff targets."""
        with self._hlock:
            handle = self.handles.get(pod.name)
        if handle is None or not handle.runtime.is_alive():
            # nothing running here (already exited): report an empty drain
            # so the pod conductor finalizes the retirement
            self.pod_coord.submit_status(
                pod.name, {"drained": {"tuplesDropped": 0, "handedOff": 0,
                                       "drainMs": 0.0, "clean": True}},
                requester=self.name)
            return
        self.fabric.set_draining(pod.spec["job"], pod.spec["peId"])
        handle.runtime.begin_drain(pod.status["draining"])

    def _maybe_start(self, pod: Resource) -> None:
        if not pod.spec.get("nodeName") or pod.status.get("phase") != "Pending" \
                or pod.terminating:
            return
        with self._hlock:
            if pod.name in self.handles:
                return
            cm = self.store.try_get(crds.CONFIG_MAP,
                                    crds.cm_name(pod.spec["job"], pod.spec["peId"]),
                                    pod.namespace)
            if cm is None:  # pod conductor guarantees this; guard anyway
                return
            stop = threading.Event()
            runtime = PERuntime(
                job=pod.spec["job"], pe_id=pod.spec["peId"],
                metadata=cm.spec["data"], fabric=self.fabric, rest=self.rest,
                launch_count=pod.spec.get("launchCount", 0), stop_event=stop,
                on_exit=self._on_runtime_exit)
            self.handles[pod.name] = PodHandle(runtime, stop)
        self.pod_coord.submit_status(pod.name, {"phase": "Running"},
                                     requester=self.name)
        runtime.start()

    def _on_runtime_exit(self, runtime: PERuntime) -> None:
        pod_name = crds.pod_name(runtime.job, runtime.pe_id)
        with self._hlock:
            self.handles.pop(pod_name, None)
        if runtime.crashed:
            self.pod_coord.submit_status(pod_name, {"phase": "Failed"},
                                         requester=self.name)
        elif runtime.drain_stats is not None:
            # drained: the pod conductor finalizes the retirement on this
            self.pod_coord.submit_status(
                pod_name, {"phase": "Succeeded",
                           "drained": runtime.drain_stats},
                requester=self.name)
        elif not runtime.stop_event.is_set():
            self.pod_coord.submit_status(pod_name, {"phase": "Succeeded"},
                                         requester=self.name)

    def stop_pod(self, pod_name: str, timeout: float = 5.0) -> None:
        with self._hlock:
            handle = self.handles.pop(pod_name, None)
        if handle:
            handle.stop_event.set()
            handle.runtime.join(timeout=timeout)

    def kill_pod(self, pod_name: str) -> bool:
        """Simulate an involuntary PE crash (test/benchmark hook)."""
        with self._hlock:
            handle = self.handles.pop(pod_name, None)
        if not handle:
            return False
        handle.stop_event.set()
        handle.runtime.join(timeout=5.0)
        self.pod_coord.submit_status(pod_name, {"phase": "Failed"},
                                     requester="chaos")
        return True

    def stop_all(self) -> None:
        with self._hlock:
            names = list(self.handles)
        for n in names:
            self.stop_pod(n)
