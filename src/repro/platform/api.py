"""Typed declarative API client — the platform's only mutation surface.

The paper's lesson (§3.3, §5) is that a cloud-native platform should treat
the cluster manager's API machinery as its own control surface: state lives
in custom resources, life cycle is tracked by finalizers and conditions,
and every actor mutates through one declarative API instead of ad-hoc
store calls.  ``ApiClient`` finishes that move for this repo:

- one typed handle per kind (``api.jobs``, ``api.pes``, ``api.pods``,
  ``api.parallel_regions``, …) so call sites read like a real client-go;
- **every** spec/status write routes through the kind's ``Coordinator``
  (paper §4.3 multiple-reader/single-writer), so single-writer semantics
  are enforced by construction rather than by discipline — concurrent
  agents physically cannot race a CAS against each other;
- declarative verbs: ``apply`` (create-or-replace with spec merge),
  ``patch``/``patch_status``, ``set_condition`` (stamping
  ``observedGeneration``), ``add_finalizer``/``remove_finalizer``,
  ``delete`` with foreground cascade, and watch-based
  ``wait_for_condition`` (no spin-polling).

Reads go straight to the store (multiple readers are free); creations go
through the coordinator lock so create-then-modify sequences from two
actors serialize the same way modifications do.
"""

from __future__ import annotations

import copy
from typing import Callable, Optional

from ..core import (
    CausalTrace,
    Coordinator,
    Resource,
    ResourceStore,
    condition_is,
    get_condition,
    set_condition,
)
from . import crds

#: handle attribute -> (resource kind, platform short name for the
#: coordinator registry — the keys ``Platform.coords`` has always used)
HANDLES = {
    "jobs": (crds.JOB, "job"),
    "pes": (crds.PE, "pe"),
    "pods": (crds.POD, "pod"),
    "parallel_regions": (crds.PARALLEL_REGION, "pr"),
    "consistent_regions": (crds.CONSISTENT_REGION, "cr"),
    "metrics": (crds.METRICS, "metrics"),
    "scaling_policies": (crds.SCALING_POLICY, "policy"),
    "slos": (crds.SLO, "slo"),
    "fault_injections": (crds.FAULT_INJECTION, "fault"),
    "config_maps": (crds.CONFIG_MAP, "cm"),
    "services": (crds.SERVICE, "svc"),
    "imports": (crds.IMPORT, "import"),
    "exports": (crds.EXPORT, "export"),
    "hostpools": (crds.HOSTPOOL, "hostpool"),
    "nodes": (crds.NODE, "node"),
    "standby_policies": (crds.STANDBY_POLICY, "standby"),
}


class KindApi:
    """Typed handle for one resource kind: reads from the store, writes
    serialized through the kind's coordinator."""

    def __init__(self, store: ResourceStore, kind: str, namespace: str,
                 coord: Coordinator):
        self.store = store
        self.kind = kind
        self.namespace = namespace
        self.coord = coord

    # ---------------------------------------------------------------- reads

    def get(self, name: str) -> Resource:
        return self.store.get(self.kind, name, self.namespace)

    def try_get(self, name: str) -> Optional[Resource]:
        return self.store.try_get(self.kind, name, self.namespace)

    def exists(self, name: str) -> bool:
        return self.store.exists(self.kind, name, self.namespace)

    def list(self, label_selector: Optional[dict] = None) -> list:
        return self.store.list(kind=self.kind, namespace=self.namespace,
                               label_selector=label_selector)

    def condition(self, name: str, cond_type: str) -> Optional[dict]:
        res = self.try_get(name)
        return get_condition(res, cond_type) if res is not None else None

    def condition_is(self, name: str, cond_type: str, status: str = "True",
                     min_generation: Optional[int] = None) -> bool:
        res = self.try_get(name)
        return res is not None and condition_is(res, cond_type, status,
                                                min_generation=min_generation)

    # --------------------------------------------------------------- writes

    def create(self, res: Resource) -> Resource:
        assert res.kind == self.kind, f"{res.kind} through the {self.kind} api"
        with self.coord.lock:  # serialize with this kind's modifications
            out = self.store.create(res)
        if self.coord.trace is not None:
            self.coord.trace.record(self.coord.name, "create", out.key)
        return out

    def apply(self, res: Resource, requester: str = "?") -> Resource:
        """Create-or-replace with spec-merge semantics, serialized through
        the coordinator (the declarative verb for 'make it look like this').
        Delegates to ``ResourceStore.apply`` so there is exactly one merge
        implementation."""
        assert res.kind == self.kind, f"{res.kind} through the {self.kind} api"
        with self.coord.lock:
            out = self.store.apply(res)
        if self.coord.trace is not None:
            self.coord.trace.record(self.coord.name, "modify", out.key,
                                    f"for={requester}")
        return out

    def edit(self, name: str, command: Callable[[Resource], None],
             requester: str = "?") -> Optional[Resource]:
        """Arbitrary serialized read-modify-write (escape hatch; prefer the
        declarative verbs)."""
        return self.coord.submit(name, command, requester=requester)

    def patch(self, name: str, spec_patch: dict,
              requester: str = "?") -> Optional[Resource]:
        def command(res: Resource) -> None:
            res.spec.update(copy.deepcopy(spec_patch))

        return self.coord.submit(name, command, requester=requester)

    def patch_status(self, name: str, patch: dict,
                     requester: str = "?") -> Optional[Resource]:
        return self.coord.submit_status(name, patch, requester=requester)

    def set_condition(self, name: str, cond_type: str, status: str,
                      reason: str = "", message: str = "",
                      requester: str = "?") -> Optional[Resource]:
        """Upsert a status condition, stamping ``observedGeneration`` with
        the generation current at write time."""
        def command(res: Resource) -> None:
            set_condition(res, cond_type, status, reason=reason,
                          message=message)

        return self.coord.submit(name, command, requester=requester)

    # ------------------------------------------------------------ life cycle

    def add_finalizer(self, name: str, finalizer: str,
                      requester: str = "?") -> Optional[Resource]:
        def command(res: Resource) -> None:
            if finalizer not in res.finalizers:
                res.finalizers.append(finalizer)

        return self.coord.submit(name, command, requester=requester)

    def remove_finalizer(self, name: str, finalizer: str,
                         requester: str = "?") -> Optional[Resource]:
        """Remove a finalizer (reaping the object if it was terminating and
        this was the last one)."""
        def command(res: Resource) -> None:
            if finalizer in res.finalizers:
                res.finalizers.remove(finalizer)

        return self.coord.submit(name, command, requester=requester)

    def delete(self, name: str, propagation: str = "orphan") -> bool:
        """Two-phase-aware delete; ``propagation="foreground"`` cascades
        through owner-reference dependents (see ``ResourceStore.delete``)."""
        with self.coord.lock:
            ok = self.store.try_delete(self.kind, name, self.namespace,
                                       propagation=propagation)
        if ok and self.coord.trace is not None:
            self.coord.trace.record(
                self.coord.name, "delete",
                (self.kind, self.namespace, name), propagation)
        return ok

    # ----------------------------------------------------------------- waits

    def wait_for_condition(self, name: str, cond_type: str,
                           status: str = "True", timeout: float = 30.0,
                           min_generation: Optional[int] = None) -> bool:
        return self.store.wait_for_condition(
            self.kind, name, cond_type, status=status,
            namespace=self.namespace, timeout=timeout,
            min_generation=min_generation)

    def wait_deleted(self, name: str, timeout: float = 30.0) -> bool:
        return self.store.wait_deleted(self.kind, name,
                                       namespace=self.namespace,
                                       timeout=timeout)


class ApiClient:
    """Per-kind typed handles over one namespace, sharing one coordinator
    per kind.  Pass ``coords`` to reuse a platform's registry: the dict is
    adopted (and filled) IN PLACE, so every ApiClient built over the same
    registry shares the same writer lock per kind — two actors can never
    end up with private coordinators for one kind."""

    jobs: KindApi
    pes: KindApi
    pods: KindApi
    parallel_regions: KindApi
    consistent_regions: KindApi
    metrics: KindApi
    scaling_policies: KindApi
    slos: KindApi
    fault_injections: KindApi
    config_maps: KindApi
    services: KindApi
    imports: KindApi
    exports: KindApi
    hostpools: KindApi
    nodes: KindApi
    standby_policies: KindApi

    def __init__(self, store: ResourceStore, namespace: str = "default",
                 coords: Optional[dict] = None,
                 trace: Optional[CausalTrace] = None):
        self.store = store
        self.namespace = namespace
        self.trace = trace
        self.coords = coords if coords is not None else {}
        self._by_kind: dict = {}
        for attr, (kind, short) in HANDLES.items():
            coord = self.coords.get(short)
            if coord is None:
                coord = Coordinator(store, kind, namespace, trace=trace)
                self.coords[short] = coord
            handle = KindApi(store, kind, namespace, coord)
            setattr(self, attr, handle)
            self._by_kind[kind] = handle

    def for_kind(self, kind: str) -> KindApi:
        """The handle for a kind string (generic actors; prefer the typed
        attributes at call sites)."""
        return self._by_kind[kind]


def ensure_api(api: Optional[ApiClient], store: ResourceStore,
               namespace: Optional[str], coords: Optional[dict],
               trace: Optional[CausalTrace]) -> ApiClient:
    """The one fallback used by every actor constructor: reuse the injected
    client (what ``Platform`` always does) or build one over the shared
    coords registry (tests constructing actors standalone)."""
    if api is not None:
        return api
    return ApiClient(store, namespace or "default", coords=coords,
                     trace=trace)


__all__ = ["ApiClient", "KindApi", "HANDLES", "ensure_api"]
