"""SLO verdict plane: objectives in, ``Met``/``Violated`` conditions out.

The ``SLO`` CRD states a job's contract (delivery-latency targets, a loss
budget, a recovery-time bound); the ``SLOConductor`` is the judge.  It
observes the job's ``Metrics`` rollups (which carry the sink digests'
delivery-latency percentiles and the drop ledger) and the span tracer's
``recover`` spans (pod failure detected -> replacement connected), folds
them into an error-budget ledger, and writes the verdict back as the
complementary ``Met``/``Violated`` condition pair — so a chaos or benchmark
run produces a machine-checkable pass/fail instead of a vibe, and any
consumer can simply ``wait_for_condition``.

Judgement rules per dimension (a dimension whose target is ``None`` is
disabled; a dimension with no evidence yet passes):

- ``latencyP95Ms`` / ``latencyP99Ms``: the Metrics rollup's ``latencyP95``/
  ``latencyP99`` (ms) must not exceed the target;
- ``lossBudgetTuples``: cumulative ``tuplesDropped`` must not exceed the
  budget (the ledger also exposes what remains);
- ``recoveryTimeS``: no ``recover`` span for the job — completed *or still
  open* — may run longer than the bound (an in-flight recovery that has
  already blown the bound is a violation now, not when it finishes).

Like every conductor, state is recomputable: the throttle map rebuilds from
the event stream, and the ledger lives in the SLO resource's status, written
only through the slo coordinator (single writer).
"""

from __future__ import annotations

import time

from ..core import Conductor, Event, EventType, set_condition
from . import crds
from .api import ensure_api
from .tracing import span_tracer


class SLOConductor(Conductor):
    """Evaluates Metrics rollups + trace spans against SLO resources."""

    kinds = (crds.SLO, crds.METRICS, crds.JOB)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 evaluate_interval: float = 0.2, clock=time.monotonic):
        super().__init__(store, "slo-conductor", trace)
        self.namespace = namespace
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.evaluate_interval = evaluate_interval
        self.clock = clock
        self._last_eval: dict = {}  # job -> t of last verdict
        self._last_spec: dict = {}  # job -> SLO spec last judged against

    # --------------------------------------------------------------- events

    def on_event(self, event: Event) -> None:
        res = event.resource
        if res.kind == crds.JOB:
            if event.type == EventType.DELETED:
                self._last_eval.pop(res.name, None)
                self._last_spec.pop(res.name, None)
            return
        job = res.spec.get("job")
        if job is None:
            return
        if event.type == EventType.DELETED:
            if res.kind == crds.SLO:
                # the contract is gone: drop the throttle + spec-signature
                # state too, or a long-lived conductor leaks one entry per
                # retired SLO (and a re-created SLO would inherit a stale
                # spec signature and skip its immediate first verdict)
                self._last_eval.pop(job, None)
                self._last_spec.pop(job, None)
            return
        # a freshly created or reconfigured SLO gets an immediate verdict.
        # Our own verdict edits also raise SLO MODIFIED events, so force only
        # on a *spec* change — status-only echoes go through the throttle,
        # else the judge feeds itself an unthrottled event loop.
        force = False
        if res.kind == crds.SLO:
            spec_sig = tuple(sorted(res.spec.items()))
            force = self._last_spec.get(job) != spec_sig
            self._last_spec[job] = spec_sig
        self.evaluate(job, force=force)

    # ------------------------------------------------------------ observation

    def observe(self, job: str) -> dict:
        """The evidence for one job: Metrics rollup + recovery spans."""
        metrics = self.store.try_get(crds.METRICS, crds.metrics_name(job),
                                     self.namespace)
        ms = metrics.status if metrics is not None else {}
        obs = {
            "p95Ms": ms.get("latencyP95"),
            "p99Ms": ms.get("latencyP99"),
            "latencySamples": ms.get("latencySamples", 0),
            "lossTuples": ms.get("tuplesDropped", 0),
            "recoveryS": None,
            "recoveries": 0,
        }
        tracer = span_tracer(self.trace)
        if tracer is not None:
            now = self.clock()
            worst = None
            n = 0
            for s in tracer.spans(name="recover"):
                if s.attrs.get("job") != job:
                    continue
                n += 1
                elapsed = (s.t1 if s.t1 is not None else now) - s.t0
                worst = elapsed if worst is None else max(worst, elapsed)
            obs["recoveryS"] = worst
            obs["recoveries"] = n
        return obs

    @staticmethod
    def judge(spec: dict, obs: dict) -> list[str]:
        """Names of the failing dimensions (empty = Met)."""
        failing = []
        p95 = spec.get("latencyP95Ms")
        if p95 is not None and obs["p95Ms"] is not None and obs["p95Ms"] > p95:
            failing.append("latencyP95")
        p99 = spec.get("latencyP99Ms")
        if p99 is not None and obs["p99Ms"] is not None and obs["p99Ms"] > p99:
            failing.append("latencyP99")
        budget = spec.get("lossBudgetTuples")
        if budget is not None and obs["lossTuples"] > budget:
            failing.append("loss")
        bound = spec.get("recoveryTimeS")
        if bound is not None and obs["recoveryS"] is not None \
                and obs["recoveryS"] > bound:
            failing.append("recovery")
        return failing

    # ------------------------------------------------------------- verdicts

    def evaluate(self, job: str, force: bool = False) -> bool:
        """Judge one job's SLO and write ledger + conditions (throttled)."""
        now = self.clock()
        if not force and now - self._last_eval.get(job, -1e9) < self.evaluate_interval:
            return False
        slo = self.store.try_get(crds.SLO, crds.slo_name(job), self.namespace)
        if slo is None or slo.terminating:
            return False
        self._last_eval[job] = now
        obs = self.observe(job)
        failing = self.judge(slo.spec, obs)
        spec = dict(slo.spec)
        reason = "+".join(failing) if failing else "AllObjectivesWithinBudget"
        message = (f"p95={obs['p95Ms']}ms p99={obs['p99Ms']}ms "
                   f"loss={obs['lossTuples']} recovery={obs['recoveryS']}s "
                   f"samples={obs['latencySamples']}")

        def command(res) -> None:
            ledger = res.status.setdefault("ledger", {})
            ledger["evaluations"] = ledger.get("evaluations", 0) + 1
            ledger["violations"] = ledger.get("violations", 0) + bool(failing)
            ledger["burnRate"] = round(
                ledger["violations"] / ledger["evaluations"], 4)
            if obs["p95Ms"] is not None:
                ledger["worstP95Ms"] = max(ledger.get("worstP95Ms", 0.0),
                                           obs["p95Ms"])
            if obs["p99Ms"] is not None:
                ledger["worstP99Ms"] = max(ledger.get("worstP99Ms", 0.0),
                                           obs["p99Ms"])
            ledger["lossSpentTuples"] = obs["lossTuples"]
            budget = spec.get("lossBudgetTuples")
            if budget is not None:
                ledger["lossRemainingTuples"] = max(budget - obs["lossTuples"], 0)
            if obs["recoveryS"] is not None:
                ledger["worstRecoveryS"] = round(
                    max(ledger.get("worstRecoveryS", 0.0), obs["recoveryS"]), 4)
            ledger["recoveries"] = obs["recoveries"]
            ledger["lastVerdict"] = "Violated" if failing else "Met"
            ledger["lastVerdictAt"] = now
            met = "False" if failing else "True"
            violated = "True" if failing else "False"
            set_condition(res, crds.COND_SLO_MET, met,
                          reason=reason, message=message)
            set_condition(res, crds.COND_SLO_VIOLATED, violated,
                          reason=reason, message=message)

        self.api.slos.edit(slo.name, command, requester=self.name)
        self._record("verdict", slo.key,
                     ("Violated:" + reason) if failing else "Met")
        return True


__all__ = ["SLOConductor"]
