"""Pluggable scheduling subsystem: filter/score pipelines + rebalancing.

The paper's own verdict on Kubernetes (§8) is that it "has problems with
oversubscription": placement by static request counting, and nothing that
ever re-examines a placement once made.  This module replaces the seed
pods-per-core scheduler with a kube-scheduler-style plugin pipeline fed by
the node pressure plane (``cluster.NodePressureMonitor``), and adds the
re-examination half as a ``RebalanceConductor``:

- **Filters** are pure predicates ``(ctx, node) -> bool``.  The feasible
  set is their intersection, so filter *order can never change it* (pinned
  by a property test).  Capacity (requested ``resources.cores`` fitting the
  node) is applied as a *soft* filter: if it empties the feasible set the
  pipeline falls back to the hard filters only — a small test cluster
  degrades to best-effort oversubscription instead of wedging Pending pods,
  and the spread/pressure scorers then pick the least oversubscribed node.
- **Scorers** map ``(ctx, node) -> float`` (higher is better); the weighted
  sum ranks the feasible set with a deterministic tie-break
  ``(-score, node name)`` so placements are reproducible under the
  testsuite's interleavings.
- The **binding decision runs inside the pod coordinator's writer lock**
  (decide + bind are one serialized command), so two concurrent Pending
  pods can never double-book the same remaining capacity — the classic
  read-then-bind race of the seed scheduler is closed by construction.
- The **RebalanceConductor** watches Node ``Pressure`` conditions; a node
  that stays hot past ``sustain_s`` gets one hosted region PE migrated off
  it through the loss-proofed restart machinery (PR 3/4): stamp the PE
  ``Rebalancing`` + an ``avoidNodes`` hint, delete the pod, and let the
  launchCount causal chain recreate it — the kubelet joins the old runtime
  (final flush lands), the fabric's residual carryover preloads the new
  ring, and the scheduler's pressure scorer binds the replacement to a cold
  node.  Gated so it never races an in-flight drain or autoscale: it holds
  while any pod of the job is mid-drain, requires a fresh ``FullHealth``
  condition, and the autoscale conductor symmetrically holds while a
  ``Rebalancing`` condition stands.
"""

from __future__ import annotations

import time

from ..core import Controller, Conductor, Coordinator, Event, EventType, \
    Resource, ResourceStore, condition_is, get_condition, set_condition
from . import crds
from .api import ensure_api
from .tracing import migrate_token, pod_token, span_tracer

#: Requested cores assumed for a pod whose spec carries no ``resources``
#: block (naked pods, pre-refactor WAL replays).
DEFAULT_POD_CORES = 0.5


def pod_cores(pod_spec: dict) -> float:
    """A pod's requested cores from its ``pod_spec`` (see ``crds.make_pod``)."""
    res = (pod_spec or {}).get("resources") or {}
    try:
        return float(res.get("cores", DEFAULT_POD_CORES))
    except (TypeError, ValueError):
        return DEFAULT_POD_CORES


def job_mid_drain(store: ResourceStore, namespace: str, job: str) -> bool:
    """True while a scale-down drain of ``job`` is still in flight (a pod
    carries the ``streams/drain`` finalizer — or a drain request — without
    a drained report yet).  Shared gate: the autoscale conductor holds its
    decisions and the rebalance conductor holds its migrations on it."""
    for pod in store.list(crds.POD, namespace, crds.job_labels(job)):
        mid_drain = (crds.DRAIN_FINALIZER in pod.finalizers
                     or pod.status.get("draining"))
        if mid_drain and not pod.status.get("drained"):
            return True
    return False


# ------------------------------------------------------------------ context


class SchedContext:
    """One scheduling cycle's view of the world: the pod to place, the
    candidate nodes (name-sorted — the determinism anchor), and the pods
    already bound, grouped by node."""

    def __init__(self, pod: Resource, nodes: list, placed: list):
        self.pod = pod
        self.nodes = sorted(nodes, key=lambda n: n.name)
        self.placed = [p for p in placed if p.spec.get("nodeName")]
        self.by_node: dict = {}
        for p in self.placed:
            self.by_node.setdefault(p.spec["nodeName"], []).append(p)
        self.want = pod.spec.get("pod_spec", {}) or {}

    def pods_on(self, node_name: str) -> list:
        return self.by_node.get(node_name, [])

    def used_cores(self, node_name: str) -> float:
        return sum(pod_cores(p.spec.get("pod_spec", {}))
                   for p in self.pods_on(node_name))

    @staticmethod
    def pod_labels(p: Resource) -> dict:
        return (p.spec.get("pod_spec", {}) or {}).get("labels", {})


# ------------------------------------------------------------------ filters


class ForcedNodeFilter:
    """``placement.host`` -> the pod runs there or nowhere (§6.2)."""

    name = "forced-node"

    def feasible(self, ctx: SchedContext, node: Resource) -> bool:
        forced = ctx.want.get("nodeName")
        return not forced or node.name == forced


class NodeAffinityFilter:
    """Hostpool tags must all appear among the node's labels (§6.2)."""

    name = "node-affinity"

    def feasible(self, ctx: SchedContext, node: Resource) -> bool:
        tags = set(ctx.want.get("nodeAffinityTags") or ())
        return tags.issubset(set(node.labels))


class PodAntiAffinityFilter:
    """No pod on the node may carry a label this pod anti-affines to."""

    name = "pod-anti-affinity"

    def feasible(self, ctx: SchedContext, node: Resource) -> bool:
        anti = ctx.want.get("podAntiAffinity") or ()
        return not any(lbl in ctx.pod_labels(p)
                       for p in ctx.pods_on(node.name) for lbl in anti)


class PodAffinityFilter:
    """If any placed pod carries an affinity label, only its nodes are
    feasible (colocate semantics; vacuously true while none exists)."""

    name = "pod-affinity"

    def feasible(self, ctx: SchedContext, node: Resource) -> bool:
        affinity = ctx.want.get("podAffinity") or ()
        if not affinity:
            return True
        anywhere = [p for p in ctx.placed
                    if any(lbl in ctx.pod_labels(p) for lbl in affinity)]
        if not anywhere:
            return True
        return any(p.spec["nodeName"] == node.name for p in anywhere)


class CapacityFilter:
    """Requested cores (this pod + everything already bound) must fit the
    node.  Soft: the pipeline runner falls back to the hard filters when
    this empties the feasible set (see the module docstring)."""

    name = "capacity"
    soft = True

    def feasible(self, ctx: SchedContext, node: Resource) -> bool:
        cores = node.spec.get("cores", 8)
        return ctx.used_cores(node.name) + pod_cores(ctx.want) <= cores


# ------------------------------------------------------------------ scorers


class SpreadScorer:
    """Prefer the node with the most free *requested* capacity."""

    name = "spread"

    def __init__(self, weight: float = 1.0):
        self.weight = weight

    def score(self, ctx: SchedContext, node: Resource) -> float:
        cores = max(node.spec.get("cores", 8), 1e-9)
        return 1.0 - min(1.0, ctx.used_cores(node.name) / cores)


class PackingScorer:
    """Bin-pack: prefer the fullest node that still fits (consolidation
    profiles; the inverse of spread)."""

    name = "packing"

    def __init__(self, weight: float = 1.0):
        self.weight = weight

    def score(self, ctx: SchedContext, node: Resource) -> float:
        cores = max(node.spec.get("cores", 8), 1e-9)
        return min(1.0, ctx.used_cores(node.name) / cores)


class PressureAvoidScorer:
    """Prefer cold nodes: score decays with the pressure plane's live
    ``status.pressure.score`` (pods-per-core + ring fill, written by the
    kubelet heartbeat) and collapses to 0 while the ``Pressure`` condition
    stands — static requests lie, the pressure plane does not."""

    name = "pressure-avoid"

    def __init__(self, weight: float = 2.0):
        self.weight = weight

    def score(self, ctx: SchedContext, node: Resource) -> float:
        if condition_is(node, crds.COND_PRESSURE, "True"):
            return 0.0
        raw = (node.status.get("pressure") or {}).get("score", 0.0)
        return 1.0 / (1.0 + max(raw, 0.0))


class AvoidHintScorer:
    """Soft repulsion from ``pod_spec.avoidNodes`` (the rebalance
    conductor's hint): a migrated pod should not bounce straight back to
    the hot node it just left, but if the hinted nodes are the only
    feasible ones the hint loses (all candidates tie at 0)."""

    name = "avoid-hint"

    def __init__(self, weight: float = 3.0):
        self.weight = weight

    def score(self, ctx: SchedContext, node: Resource) -> float:
        return 0.0 if node.name in (ctx.want.get("avoidNodes") or ()) else 1.0


class SeedSpreadScorer:
    """The seed load factor, kept as the ``seed`` profile's only scorer:
    placed-pod *count* over spec cores — blind to requested resources and
    to live pressure (the §8 oversubscription pathology the ``oversub``
    benchmark reproduces)."""

    name = "seed-spread"
    weight = 1.0

    def score(self, ctx: SchedContext, node: Resource) -> float:
        return -len(ctx.pods_on(node.name)) / max(node.spec.get("cores", 8), 1)


# ----------------------------------------------------------------- pipeline


def feasible_set(ctx: SchedContext, filters: list) -> list:
    """Intersection of all filter predicates — order-independent by
    construction (pinned by a property test)."""
    return [n for n in ctx.nodes
            if all(f.feasible(ctx, n) for f in filters)]


def rank(ctx: SchedContext, nodes: list, scorers: list) -> list:
    """Weighted-sum ranking with the deterministic ``(-score, name)``
    tie-break; returns node names, best first."""
    scored = [(-sum(s.weight * s.score(ctx, n) for s in scorers), n.name)
              for n in nodes]
    scored.sort()
    return [name for _, name in scored]


PROFILES = {
    # pressure-aware default: capacity accounting + live pressure avoidance
    "pressure": lambda: (
        [ForcedNodeFilter(), NodeAffinityFilter(), PodAntiAffinityFilter(),
         PodAffinityFilter(), CapacityFilter()],
        [SpreadScorer(1.0), PressureAvoidScorer(2.0), AvoidHintScorer(3.0)]),
    # consolidation: same feasibility, pack instead of spread
    "pack": lambda: (
        [ForcedNodeFilter(), NodeAffinityFilter(), PodAntiAffinityFilter(),
         PodAffinityFilter(), CapacityFilter()],
        [PackingScorer(1.0), PressureAvoidScorer(2.0), AvoidHintScorer(3.0)]),
    # the pre-refactor behaviour, kept as the benchmark baseline
    "seed": lambda: (
        [ForcedNodeFilter(), NodeAffinityFilter(), PodAntiAffinityFilter(),
         PodAffinityFilter()],
        [SeedSpreadScorer()]),
}


class SchedulerController(Controller):
    """Assigns ``nodeName`` to pending pods (paper §6.2 semantics) through
    the filter -> score plugin pipeline.

    The placement decision and the binding are one command on the pod
    coordinator: the feasible set and scores are computed from store state
    *under the writer lock*, so concurrent Pending pods serialize and the
    capacity each one sees already includes every earlier binding."""

    def __init__(self, store: ResourceStore, pod_coord: Coordinator,
                 namespace=None, trace=None, profile: str = "pressure",
                 filters: list | None = None, scorers: list | None = None):
        super().__init__(store, crds.POD, namespace, "scheduler", trace)
        self.pod_coord = pod_coord
        self.profile = profile
        default_filters, default_scorers = PROFILES[profile]()
        self.filters = default_filters if filters is None else filters
        self.scorers = default_scorers if scorers is None else scorers

    def on_addition(self, res: Resource) -> None:
        self._maybe_schedule(res)

    def on_modification(self, old, new) -> None:
        if not new.spec.get("nodeName") and new.status.get("phase") == "Pending":
            self._maybe_schedule(new)

    # ------------------------------------------------------------ decisions

    def decide(self, pod: Resource) -> str | None:
        """Pure decision from current store state: the node to bind, or
        None when no node is feasible."""
        ns = pod.namespace
        nodes = self.store.list(kind=crds.NODE)
        if not nodes:
            return None
        placed = [p for p in self.store.list(crds.POD, ns)
                  if p.spec.get("nodeName")]
        ctx = SchedContext(pod, nodes, placed)
        hard = [f for f in self.filters if not getattr(f, "soft", False)]
        soft = [f for f in self.filters if getattr(f, "soft", False)]
        feasible = feasible_set(ctx, hard + soft)
        if not feasible and soft:
            # soft-filter fallback: oversubscribe rather than wedge; the
            # scorers pick the least oversubscribed feasible node
            feasible = feasible_set(ctx, hard)
        if not feasible:
            return None
        return rank(ctx, feasible, self.scorers)[0]

    def _maybe_schedule(self, pod: Resource) -> None:
        if pod.spec.get("nodeName") or pod.terminating:
            return
        if not self.store.list(kind=crds.NODE):
            return  # no substrate yet; a node addition re-kicks pending pods

        def place(res: Resource) -> None:
            if res.spec.get("nodeName") or res.terminating:
                return  # lost the race to an earlier command; nothing to do
            node_name = self.decide(res)
            if node_name is None:
                res.status["phase"] = "Unschedulable"
                return
            res.spec["nodeName"] = node_name
            if res.status.get("phase") == "Unschedulable":
                res.status["phase"] = "Pending"  # revived (node added/freed)

        sp = span_tracer(self.trace)
        if sp is None:
            out = self.pod_coord.submit(pod.name, place, requester=self.name)
        else:
            # decide+bind as one timed span, parented to whatever lifecycle
            # operation is driving this pod (recover / migrate chain)
            with sp.span(self.name, "decide+bind", pod.key,
                         parent=sp.context(pod_token(pod.name))) as span:
                out = self.pod_coord.submit(pod.name, place,
                                            requester=self.name)
                span.attrs["node"] = \
                    out.spec.get("nodeName") if out is not None else None
        if out is not None and out.spec.get("nodeName"):
            self._record("bind", out.key, out.spec["nodeName"])

    def kick_pending(self) -> int:
        """Re-run placement for every unbound pod (Unschedulable included);
        called when capacity appears (node addition).  Returns how many
        pods were submitted for (re)scheduling."""
        kicked = 0
        for pod in self.store.list(crds.POD, self.namespace):
            if pod.spec.get("nodeName") or pod.terminating:
                continue
            if pod.status.get("phase") in ("Pending", "Unschedulable"):
                self._maybe_schedule(pod)
                kicked += 1
        return kicked


class NodeController(Controller):
    """Node life-cycle: a node addition re-kicks unschedulable pods (new
    capacity must not strand them Pending forever).  Also the event source
    conductors (rebalance) register with for node pressure updates."""

    def __init__(self, store: ResourceStore, namespace=None, trace=None,
                 scheduler: SchedulerController | None = None):
        super().__init__(store, crds.NODE, namespace, "node-controller", trace)
        self.scheduler = scheduler

    def on_addition(self, res: Resource) -> None:
        if self.scheduler is not None:
            self.scheduler.kick_pending()


# ---------------------------------------------------------------- rebalance


class RebalanceConductor(Conductor):
    """Detects sustained hot nodes from the pressure plane and migrates one
    hosted region PE off them — the placement re-examination Kubernetes
    lacks (paper §8).  See the module docstring for the zero-loss
    mechanics and the gating rules."""

    kinds = (crds.NODE, crds.POD)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 enabled: bool = True, sustain_s: float = 1.0,
                 cooldown: float = 3.0, clock=time.time):
        super().__init__(store, "rebalance-conductor", trace)
        self.namespace = namespace
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.enabled = enabled
        self.sustain_s = sustain_s
        self.cooldown = cooldown
        self.clock = clock
        self.migrations = 0
        self._last_migration = 0.0

    # --------------------------------------------------------------- events

    def on_event(self, event: Event) -> None:
        res = event.resource
        if res.kind == crds.POD:
            self._maybe_complete(event)
            return
        if not self.enabled or event.type == EventType.DELETED:
            return
        cond = get_condition(res, crds.COND_PRESSURE)
        if cond is None or cond.get("status") != "True":
            return
        now = self.clock()
        if now - cond.get("lastTransitionTime", now) < self.sustain_s:
            return  # hot, but not yet *sustained* hot
        if now - self._last_migration < self.cooldown:
            return
        self._maybe_migrate(res, now)

    def _maybe_complete(self, event: Event) -> None:
        """The migrated PE's REPLACEMENT pod reported Running+connected:
        the migration is over — drop the ``Rebalancing`` condition (the
        autoscale conductor resumes) and the ``avoidNodes`` hint (it must
        not outlive the hot episode it was aimed at: a later restart
        should be free to use that node again).

        Guarded against the victim's own stale status events: between the
        mark and the kubelet joining the runtime, the victim still patches
        Running+connected status — only a pod of a LATER launch than the
        one migrated away (``rebalancedLaunch``) completes the migration."""
        pod = event.resource
        if event.type == EventType.DELETED or pod.terminating or \
                not (pod.status.get("phase") == "Running"
                     and pod.status.get("connected")):
            return
        pe_name = crds.pe_name(pod.spec.get("job", ""), pod.spec.get("peId", -1))
        pe = self.store.try_get(crds.PE, pe_name, self.namespace)
        if pe is None or not condition_is(pe, crds.COND_REBALANCING, "True"):
            return
        if pod.spec.get("launchCount", 0) <= \
                pe.status.get("rebalancedLaunch", -1):
            return  # the old incarnation's tail, not the replacement

        def complete(res: Resource) -> None:
            spec = dict(res.spec.get("podSpec") or {})
            spec.pop("avoidNodes", None)
            res.spec["podSpec"] = spec
            res.status.pop("rebalancedLaunch", None)
            set_condition(res, crds.COND_REBALANCING, "False",
                          reason="MigrationComplete")

        self.api.pes.edit(pe_name, complete, requester=self.name)
        sp = span_tracer(self.trace)
        if sp is not None:
            sp.end_span(sp.detach(migrate_token(pe_name)),
                        to=pod.spec.get("nodeName", "?"))
        self._record("migrated", pe.key, pod.spec.get("nodeName", "?"))

    # ------------------------------------------------------------ migration

    def _cold_node_exists(self, hot: str) -> bool:
        for node in self.store.list(kind=crds.NODE):
            if node.name != hot and \
                    not condition_is(node, crds.COND_PRESSURE, "True"):
                return True
        return False

    def _rebalancing_in_flight(self) -> bool:
        return any(condition_is(pe, crds.COND_REBALANCING, "True")
                   for pe in self.store.list(crds.PE, self.namespace))

    def _region_pe(self, pod: Resource) -> bool:
        """Only PEs inside a parallel region are migration candidates:
        siblings absorb the restart blip, and accounting pods (sinks) keep
        their counters."""
        cm = self.store.try_get(
            crds.CONFIG_MAP, crds.cm_name(pod.spec["job"], pod.spec["peId"]),
            self.namespace)
        ops = (cm.spec.get("data", {}).get("operators")
               if cm is not None else None) or [{}]
        return ops[0].get("region") is not None

    def pick_victim(self, node_name: str) -> Resource | None:
        """The region pod to move: Running, not draining/terminating, not
        host-pinned; highest backpressure first, name tie-break."""
        candidates = []
        for pod in self.store.list(crds.POD, self.namespace):
            if pod.spec.get("nodeName") != node_name:
                continue
            if pod.status.get("phase") != "Running" or pod.terminating or \
                    pod.status.get("draining"):
                continue
            if pod.spec.get("standby"):
                continue  # standbys hold no traffic; moving one fixes nothing
            if (pod.spec.get("pod_spec", {}) or {}).get("nodeName"):
                continue  # host-pinned: the scheduler would re-bind it here
            if not self._region_pe(pod):
                continue
            bp = (pod.status.get("metrics") or {}).get("backpressure", 0.0)
            candidates.append((-bp, pod.name, pod))
        candidates.sort(key=lambda c: c[:2])
        return candidates[0][2] if candidates else None

    def _maybe_migrate(self, node: Resource, now: float) -> None:
        if self._rebalancing_in_flight():
            return  # one migration at a time: let the cluster resettle
        if not self._cold_node_exists(node.name):
            return  # nowhere better to go; migrating would reshuffle, not fix
        victim = self.pick_victim(node.name)
        if victim is None:
            return
        job = victim.spec["job"]
        job_res = self.store.try_get(crds.JOB, job, self.namespace)
        if job_res is None or job_res.terminating:
            return
        if not condition_is(job_res, crds.COND_FULL_HEALTH, "True",
                            min_generation=job_res.generation):
            return  # restart churn / in-flight scale-up: do not pile on
        if job_mid_drain(self.store, self.namespace, job):
            return  # never race a scale-down drain
        pe_name = crds.pe_name(job, victim.spec["peId"])
        victim_launch = victim.spec.get("launchCount", 0)

        def mark(res: Resource) -> None:
            if res.terminating:
                return
            spec = dict(res.spec.get("podSpec") or {})
            spec["avoidNodes"] = [node.name]
            res.spec["podSpec"] = spec
            # completion trigger: only a pod of a LATER launch than the
            # victim proves the replacement is up (the victim keeps
            # heartbeating Running+connected until the kubelet joins it)
            res.status["rebalancedLaunch"] = victim_launch
            set_condition(res, crds.COND_REBALANCING, "True",
                          reason="HotNode", message=node.name)

        marked = self.api.pes.edit(pe_name, mark, requester=self.name)
        if marked is None or marked.terminating or \
                not condition_is(marked, crds.COND_REBALANCING, "True"):
            return  # a teardown/drain got the PE first
        self._last_migration = now
        self.migrations += 1
        sp = span_tracer(self.trace)
        if sp is not None:
            # root of the migration span tree: the restart chain below
            # (recover -> decide+bind -> start-pod) parents under it via
            # the pod context token; _maybe_complete closes it
            root = sp.start_span(self.name, "migrate", marked.key,
                                 job=job, pe=victim.spec["peId"],
                                 off=node.name)
            sp.attach(migrate_token(pe_name), root)
        # the loss-proofed restart chain (PR 3/4): kubelet joins the old
        # runtime (its tail flushes), unpublish stashes the ring, the pod
        # controller bumps launchCount, the pod conductor recreates, the
        # scheduler binds the replacement to a cold node, and the fresh
        # publish preloads the stashed residuals — zero tuples lost
        self.api.pods.delete(victim.name)
        self._record("migrate", victim.key, f"off={node.name}")


__all__ = [
    "AvoidHintScorer", "CapacityFilter", "DEFAULT_POD_CORES",
    "ForcedNodeFilter", "NodeAffinityFilter", "NodeController",
    "PackingScorer", "PodAffinityFilter", "PodAntiAffinityFilter",
    "PressureAvoidScorer", "PROFILES", "RebalanceConductor", "SchedContext",
    "SchedulerController", "SeedSpreadScorer", "SpreadScorer", "feasible_set",
    "job_mid_drain", "pod_cores", "rank",
]
