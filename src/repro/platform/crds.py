"""Custom resource definitions (paper Fig. 4) and their constructors.

All platform state lives in these resources; everything else is ephemeral
and recomputable.  Naming is hierarchical and deterministic (paper §7.5):
PE ids are local to the job, port ids local to the PE, pod/configmap/service
names are pure functions of (job, pe id) — nothing is stored that can be
computed.
"""

from __future__ import annotations

from ..core import OwnerRef, Resource

JOB = "Job"
PE = "ProcessingElement"
PARALLEL_REGION = "ParallelRegion"
HOSTPOOL = "HostPool"
IMPORT = "Import"
EXPORT = "Export"
CONSISTENT_REGION = "ConsistentRegion"
CONFIG_MAP = "ConfigMap"
POD = "Pod"
SERVICE = "Service"
NODE = "Node"
TEST_SUITE = "TestSuite"
METRICS = "Metrics"
SCALING_POLICY = "ScalingPolicy"

CUSTOM_KINDS = (JOB, PE, PARALLEL_REGION, HOSTPOOL, IMPORT, EXPORT,
                CONSISTENT_REGION, TEST_SUITE, METRICS, SCALING_POLICY)
K8S_KINDS = (CONFIG_MAP, POD, SERVICE, NODE)


# ------------------------------------------------------------ name helpers


def pe_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def pod_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def cm_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}-config"


def service_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def pr_name(job: str, region: str) -> str:
    return f"{job}-pr-{region}"


def cr_name(job: str, region: str) -> str:
    return f"{job}-cr-{region}"


def metrics_name(job: str) -> str:
    return f"{job}-metrics"


def policy_name(job: str, region: str) -> str:
    return f"{job}-scale-{region}"


def job_labels(job: str) -> dict:
    return {"repro.ibm.com/job": job}


# ----------------------------------------------------------- constructors


def make_job(name: str, spec: dict, namespace: str = "default") -> Resource:
    return Resource(kind=JOB, name=name, namespace=namespace, spec=spec,
                    labels=job_labels(name))


def make_pe(job: str, pe_id: int, spec: dict, namespace: str = "default") -> Resource:
    return Resource(
        kind=PE, name=pe_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, **spec},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"launchCount": 0},
    )


def make_config_map(job: str, pe_id: int, data: dict, generation: int,
                    namespace: str = "default") -> Resource:
    return Resource(
        kind=CONFIG_MAP, name=cm_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "data": data,
              "jobGeneration": generation},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_service(job: str, pe_id: int, ports: list,
                 namespace: str = "default") -> Resource:
    return Resource(
        kind=SERVICE, name=service_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "ports": ports},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_pod(job: str, pe_id: int, pod_spec: dict, launch_count: int,
             generation: int, namespace: str = "default") -> Resource:
    return Resource(
        kind=POD, name=pod_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "launchCount": launch_count,
              "jobGeneration": generation, **pod_spec},
        labels={**job_labels(job), "repro.ibm.com/pe": str(pe_id)},
        owner_refs=(OwnerRef(PE, pe_name(job, pe_id)),),
        status={"phase": "Pending"},
    )


def make_parallel_region(job: str, region: str, width: int,
                         namespace: str = "default") -> Resource:
    return Resource(
        kind=PARALLEL_REGION, name=pr_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, "width": width},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_hostpool(job: str, name: str, tags: list,
                  namespace: str = "default") -> Resource:
    return Resource(
        kind=HOSTPOOL, name=f"{job}-hp-{name}", namespace=namespace,
        spec={"job": job, "name": name, "tags": tags},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_export(job: str, op_name: str, stream: str, properties: dict,
                namespace: str = "default") -> Resource:
    return Resource(
        kind=EXPORT, name=f"{job}-export-{op_name}", namespace=namespace,
        spec={"job": job, "operator": op_name, "stream": stream,
              "properties": properties},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_import(job: str, op_name: str, subscription: dict,
                namespace: str = "default") -> Resource:
    return Resource(
        kind=IMPORT, name=f"{job}-import-{op_name}", namespace=namespace,
        spec={"job": job, "operator": op_name, "subscription": subscription},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_consistent_region(job: str, region: str, spec: dict,
                           namespace: str = "default") -> Resource:
    return Resource(
        kind=CONSISTENT_REGION, name=cr_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, **spec},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"state": "Idle", "lastCommitted": -1},
    )


def make_metrics(job: str, namespace: str = "default") -> Resource:
    """One Metrics resource per job: the metrics plane's published rollups.

    spec is empty (there is no desired state — metrics are pure observation);
    all content lives in status, written only by the metrics coordinator.
    """
    return Resource(
        kind=METRICS, name=metrics_name(job), namespace=namespace,
        spec={"job": job},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"operators": {}, "regions": {}},
    )


def make_scaling_policy(job: str, region: str, *, min_width: int = 1,
                        max_width: int = 4, metric: str = "backpressure",
                        scale_up_at: float = 0.5, scale_down_at: float = 0.05,
                        target_per_channel: float = 0.0, step: int = 1,
                        cooldown: float = 1.0,
                        namespace: str = "default") -> Resource:
    """ScalingPolicy CRD: bounds + thresholds the autoscale conductor obeys.

    ``metric`` selects the region aggregate to scale on: "backpressure"
    (mean input-queue fill, thresholded) or "throughput" (tuples/s divided
    by ``target_per_channel`` gives the wanted width directly).
    """
    return Resource(
        kind=SCALING_POLICY, name=policy_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, "minWidth": min_width,
              "maxWidth": max_width, "metric": metric,
              "scaleUpAt": scale_up_at, "scaleDownAt": scale_down_at,
              "targetPerChannel": target_per_channel, "step": step,
              "cooldown": cooldown},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"lastScaleAt": 0.0},
    )


def make_node(name: str, cores: int = 16, labels: dict | None = None) -> Resource:
    return Resource(kind=NODE, name=name, spec={"cores": cores},
                    labels=labels or {})
