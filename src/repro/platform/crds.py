"""Custom resource definitions (paper Fig. 4) and their constructors.

All platform state lives in these resources; everything else is ephemeral
and recomputable.  Naming is hierarchical and deterministic (paper §7.5):
PE ids are local to the job, port ids local to the PE, pod/configmap/service
names are pure functions of (job, pe id) — nothing is stored that can be
computed.

Every constructor below documents its public ``spec``/``status`` fields;
``docs/ARCHITECTURE.md`` maps them back to the paper's sections.  Two
cross-cutting field families live in *operator config dicts* (carried
through ConfigMaps into the PE runtimes) rather than in their own CRD:

Emit-batching knobs (per-operator ``config``, see ``PERuntime``):

- ``emit_batch``      initial output batch size (tuples per flush); the
                      adaptive controller starts here.  Default 64.
- ``emit_batch_min``  lower bound the controller may shrink to under light
                      load (1 = per-tuple emission).  Default 1.
- ``emit_batch_max``  upper bound under backpressure.  Default 512.
- ``emit_adaptive``   enable the metrics-driven controller (default True);
                      False pins ``emit_batch`` statically (the pre-drain
                      behaviour).
- ``emit_linger``     max seconds a buffered tuple may wait before a flush;
                      the effective linger scales down with the current
                      batch size (per-tuple emission ≈ zero linger).
                      Default 0.002.

Drain knobs (job ``spec["drain"]``, consumed by ``JobController`` on a
width decrease and enforced by the PE runtime — see ``drain_config``):

- ``enabled``   drain retiring PEs before deleting their pods (default
                True); False restores the seed drop-on-retire behaviour.
- ``timeout``   seconds a retiring PE may spend pulling its input dry
                before falling back to handoff/drop (default 5.0).
- ``grace``     seconds of continuous input silence (after retiring
                upstreams unpublished) that count as "dry" (default 0.3).
"""

from __future__ import annotations

from ..core import OwnerRef, Resource

JOB = "Job"
PE = "ProcessingElement"
PARALLEL_REGION = "ParallelRegion"
HOSTPOOL = "HostPool"
IMPORT = "Import"
EXPORT = "Export"
CONSISTENT_REGION = "ConsistentRegion"
CONFIG_MAP = "ConfigMap"
POD = "Pod"
SERVICE = "Service"
NODE = "Node"
TEST_SUITE = "TestSuite"
METRICS = "Metrics"
SCALING_POLICY = "ScalingPolicy"
SLO = "SLO"
FAULT_INJECTION = "FaultInjection"
STANDBY_POLICY = "StandbyPolicy"

CUSTOM_KINDS = (JOB, PE, PARALLEL_REGION, HOSTPOOL, IMPORT, EXPORT,
                CONSISTENT_REGION, TEST_SUITE, METRICS, SCALING_POLICY, SLO,
                FAULT_INJECTION, STANDBY_POLICY)
K8S_KINDS = (CONFIG_MAP, POD, SERVICE, NODE)


# ------------------------------------------------- life cycle (conditions)
#
# Status conditions (see ``repro.core.set_condition``) are the platform's
# canonical life-cycle signals.  Every entry carries ``{type, status
# ("True"|"False"), reason, message, observedGeneration,
# lastTransitionTime}``; ``observedGeneration`` is the spec generation the
# writer had seen, so consumers can tell a fresh condition from one left
# over from a previous generation (the paper's §5 life-cycle tracking,
# expressed in Kubernetes API conventions).  The legacy scalar fields
# (``status.state``, ``status.fullHealth``) are still written for
# human-readable phase display, but gates read the conditions.

#: Job: the submission pipeline ran and all expected PEs exist.
COND_SUBMITTED = "Submitted"
#: Job: every expected pod is Running+connected (flips False on any loss).
COND_FULL_HEALTH = "FullHealth"
#: PE / Pod: a scale-down retirement is in flight; the PE is pulling its
#: input dry behind the ``streams/drain`` finalizer.
COND_DRAINING = "Draining"
#: Pod: the runtime's drain report landed (reason carries clean/timeout).
COND_DRAINED = "Drained"
#: Node: the node is oversubscribed (pods-per-core at/over the hot
#: threshold).  Written by the kubelet's pressure heartbeat; the scheduler's
#: pressure-avoidance scorer and the rebalance conductor key off it.  The
#: raw signals ride in ``status.pressure`` ({podsPerCore, ringFill,
#: heartbeatLag, score, pods, updatedAt}).
COND_PRESSURE = "Pressure"
#: Node: some hosted pod's heartbeat is stale past the straggle threshold —
#: the node-level view of the straggler monitor's per-pod signal.
COND_STRAGGLING = "Straggling"
#: PE: the rebalance conductor is migrating this PE off a hot node; its pod
#: was deleted and the replacement has not reported Running+connected yet.
#: The autoscale conductor holds decisions for the job while this stands —
#: a generation change mid-migration would re-plan under the moving PE.
COND_REBALANCING = "Rebalancing"
#: SLO: every objective dimension (latency / loss / recovery) is within its
#: target over the evaluation window.  Written only by the SLO conductor via
#: the slo coordinator; ``Met`` and ``Violated`` are always set as a
#: complementary pair so consumers can wait on either polarity.
COND_SLO_MET = "Met"
#: SLO: at least one objective dimension is out of budget; the condition
#: reason names the failing dimensions.
COND_SLO_VIOLATED = "Violated"
#: FaultInjection: the chaos conductor has fired the fault (the injection
#: timestamp rides in the condition; the ``chaos``-rooted span starts here).
COND_FAULT_INJECTED = "Injected"
#: FaultInjection: the platform healed — the fault's recovery signal (full
#: health back, drain finalized, partition window closed) was observed and
#: the chaos span ended.  Reason carries the outcome summary.
COND_FAULT_RECOVERED = "Recovered"
#: PE: the PE is alive but unreachable through the fabric (a network
#: partition, not a crash).  The operator routes around it — established
#: senders fall back to sibling handoff — instead of restarting it; the
#: condition lifts when the partition heals.  The pod controller will not
#: bump launchCount (and the straggler monitor will not mark the pod
#: Failed) while this stands.
COND_QUARANTINED = "Quarantined"
#: PE: a warm standby pod for this PE is placed, running, and holding —
#: ring preloadable, state warmed from the latest committed checkpoint.
#: While this stands the failover conductor owns the PE's failure handling:
#: the pod controller does NOT bump ``launchCount`` on a primary failure
#: (promotion replaces the delete→schedule→start→connect chain).
COND_STANDBY_READY = "StandbyReady"
#: PE: a standby promotion is in flight — the conductor has adopted the
#: standby runtime under the primary pod name and is converging the pod
#: records.  The pod conductor must not reconcile (create/delete pods for)
#: the PE while this stands, and the pod controller must not bump.
COND_PROMOTING = "Promoting"

#: Finalizer a retiring PE/Pod carries while draining: deletion only stamps
#: ``deletion_timestamp``; the drained report removes the finalizer and the
#: store reaps the object (two-phase deletion, paper §5 life-cycle offload).
DRAIN_FINALIZER = "streams/drain"
#: Finalizer on pods DOWNSTREAM of an in-flight drain (the delivery path
#: the drained tuples still need).  Tracked by the ``drainHolds`` ledger
#: (several drains can hold one pod); removed when the ledger empties.
#: Keeping it separate from ``streams/drain`` lets the store's own
#: last-finalizer bookkeeping arbitrate a pod that is BOTH draining and
#: held — no hand-rolled dual-obligation logic.
PATH_HOLD_FINALIZER = "streams/path-hold"


# ------------------------------------------------------------ name helpers


def pe_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def pod_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def cm_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}-config"


def service_name(job: str, pe_id: int) -> str:
    return f"{job}-pe-{pe_id}"


def pr_name(job: str, region: str) -> str:
    return f"{job}-pr-{region}"


def cr_name(job: str, region: str) -> str:
    return f"{job}-cr-{region}"


def metrics_name(job: str) -> str:
    return f"{job}-metrics"


def policy_name(job: str, region: str) -> str:
    return f"{job}-scale-{region}"


def slo_name(job: str) -> str:
    return f"{job}-slo"


def fault_name(job: str, tag: str) -> str:
    return f"{job}-fault-{tag}"


def standby_pod_name(job: str, pe_id: int) -> str:
    return f"{job}-standby-{pe_id}"


def standby_policy_name(job: str) -> str:
    return f"{job}-standby"


def pe_affinity_label(job: str, pe_id: int) -> str:
    """The per-PE pod label key the standby anti-affinity matches: the
    primary's pod carries it, the standby's ``podAntiAffinity`` names it,
    so the anti-affinity plugin keeps the pair on different nodes."""
    return f"repro.ibm.com/pe-{job}-{pe_id}"


def job_labels(job: str) -> dict:
    return {"repro.ibm.com/job": job}


def drain_config(spec: dict) -> dict:
    """Normalize a job spec's ``drain`` block (see the module docstring).

    Accepts ``drain: False`` / ``drain: True`` shorthands as well as the
    full dict form; always returns ``{"enabled", "timeout", "grace"}``.
    """
    raw = spec.get("drain", {})
    if raw is False or raw is True:
        raw = {"enabled": raw}
    return {"enabled": bool(raw.get("enabled", True)),
            "timeout": float(raw.get("timeout", 5.0)),
            "grace": float(raw.get("grace", 0.3))}


# ----------------------------------------------------------- constructors


def make_job(name: str, spec: dict, namespace: str = "default") -> Resource:
    """Job CRD — the user's submission (paper §6.1).

    spec:   ``app`` (application block: type streams|train|serve + its
            knobs), ``consistentRegion`` ({name, interval, operators?},
            §6.5), ``widths`` (region -> width, written by the
            ParallelRegionController on width edits; a spec change here is
            what bumps the generation, §6.3), ``fusion``
            ("one-per-op"|"per-channel"), ``drain`` (see ``drain_config``),
            ``stragglerTimeout`` (seconds of heartbeat silence before a pod
            is treated as failed), ``gcMode`` ("foreground" — the default —
            tears down by owner-ref cascade driven by finalizers; "manual"
            keeps the §8 bulk label sweep).
    status: ``state`` (Submitting|Submitted), ``jobId``,
            ``appliedGeneration``, ``expectedPEs``, ``fullHealth`` /
            ``fullHealthAt`` / ``submittedAt``, ``sourcesDone``;
            ``conditions``: ``Submitted`` (pipeline ran, every expected PE
            exists) and ``FullHealth`` (True/False as pods gain/lose
            health), each stamped with the ``observedGeneration`` the
            job-conductor had seen (a width edit bumps the generation, so a
            stale ``FullHealth=True`` is detectable).
    """
    return Resource(kind=JOB, name=name, namespace=namespace, spec=spec,
                    labels=job_labels(name))


def make_pe(job: str, pe_id: int, spec: dict, namespace: str = "default") -> Resource:
    """ProcessingElement CRD — one schedulable PE (paper §5.1).

    spec:   ``job``, ``peId`` (job-local, width-stable), ``operators``
            (fused operator names), ``podSpec`` (placement constraints from
            §6.2).
    status: ``launchCount`` (the pod causal chain's trigger: every bump
            makes the pod conductor converge a pod to it), ``state``
            ("Draining" while a retiring PE pulls its input dry on
            scale-down), and the ``Draining`` condition.  A retiring PE
            carries the ``streams/drain`` finalizer through a two-phase
            delete: it lingers terminating until the drained report removes
            the finalizer and the store reaps it.
    """
    return Resource(
        kind=PE, name=pe_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, **spec},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"launchCount": 0},
    )


def make_config_map(job: str, pe_id: int, data: dict, generation: int,
                    namespace: str = "default") -> Resource:
    """ConfigMap — a PE's graph metadata, the §6.3 restart discriminator.

    spec: ``job``, ``peId``, ``jobGeneration``, and ``data`` (the pipeline's
    per-PE ``graph_metadata``: operators with their config dicts — including
    the emit-batching knobs documented in the module docstring — input/output
    ports, widths for trainer/reducer PEs, consistentRegion).  The pod
    conductor restarts a pod iff ``data`` changed across generations.
    """
    return Resource(
        kind=CONFIG_MAP, name=cm_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "data": data,
              "jobGeneration": generation},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_service(job: str, pe_id: int, ports: list,
                 namespace: str = "default") -> Resource:
    """Service — the PE's stable network name (§5.2 computed names).

    spec: ``job``, ``peId``, ``ports`` (input port ids the fabric publishes
    under the (job, peId, portId) computed name).
    """
    return Resource(
        kind=SERVICE, name=service_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "ports": ports},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_pod(job: str, pe_id: int, pod_spec: dict, launch_count: int,
             generation: int, namespace: str = "default") -> Resource:
    """Pod — the PE's running incarnation (created ONLY by the pod conductor).

    spec:   ``job``, ``peId``, ``launchCount`` (which launch this pod
            serves), ``jobGeneration``, ``nodeName`` (bound by the
            scheduler), ``pod_spec`` (labels/affinity from §6.2, plus
            ``resources`` — ``{"cores": float}``, the pod's requested CPU
            share, filled by the pipeline from per-operator-kind defaults
            or an explicit ``placement.cores``; the scheduler's capacity
            filter and spread scorer account in requested cores, not pod
            counts — and ``avoidNodes``, a soft scheduling hint the
            rebalance conductor stamps so a migrated pod is not re-bound
            to the hot node it just left).
    status: ``phase`` (Pending|Running|Succeeded|Failed|Unschedulable),
            ``connected``, ``sourceDone``, ``heartbeat``, ``metrics`` (the
            PE's latest load sample, scraped by the metrics plane),
            ``sink`` ({seen, maxseq} progress), ``draining`` (the drain
            request written on scale-down: {requestedAt, timeout, grace,
            siblings, upstream, upstreamRestarting, downstream} — the
            kubelet forwards it to the runtime), ``drained`` (the
            runtime's drain report: {tuplesDropped, handedOff, drainMs,
            clean} — removal trigger for the ``streams/drain`` finalizer),
            ``drainHolds`` (retiring PE ids whose in-flight drains still
            need THIS pod as delivery path: while non-empty the pod carries
            the ``streams/path-hold`` finalizer so a mid-drain job teardown
            cannot reap the path the drained tuples must traverse), and
            the ``Draining`` / ``Drained`` conditions.
    """
    return Resource(
        kind=POD, name=pod_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "launchCount": launch_count,
              "jobGeneration": generation, **pod_spec},
        labels={**job_labels(job), "repro.ibm.com/pe": str(pe_id)},
        owner_refs=(OwnerRef(PE, pe_name(job, pe_id)),),
        status={"phase": "Pending"},
    )


def make_parallel_region(job: str, region: str, width: int,
                         namespace: str = "default") -> Resource:
    """ParallelRegion CRD — the elastic unit (§6.3).

    spec: ``job``, ``region``, ``width``.  Editing ``width`` (kubectl or
    the autoscale conductor) fires the generation-change causal chain; a
    decrease additionally sends the removed channels through the drain
    phase before their pods are deleted.
    """
    return Resource(
        kind=PARALLEL_REGION, name=pr_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, "width": width},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_hostpool(job: str, name: str, tags: list,
                  namespace: str = "default") -> Resource:
    """HostPool CRD — named node-tag set for placement (§6.2).

    spec: ``job``, ``name``, ``tags`` (node labels operators may pin to via
    ``placement.hostpool_tags``).
    """
    return Resource(
        kind=HOSTPOOL, name=f"{job}-hp-{name}", namespace=namespace,
        spec={"job": job, "name": name, "tags": tags},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_export(job: str, op_name: str, stream: str, properties: dict,
                namespace: str = "default") -> Resource:
    """Export CRD — a published stream (§6.4 pub/sub).

    spec: ``job``, ``operator``, ``stream`` (name importers may subscribe
    to), ``properties`` (key/value set for property-based subscription),
    ``peId`` (the exporting PE, filled by the job controller).
    """
    return Resource(
        kind=EXPORT, name=f"{job}-export-{op_name}", namespace=namespace,
        spec={"job": job, "operator": op_name, "stream": stream,
              "properties": properties},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_import(job: str, op_name: str, subscription: dict,
                namespace: str = "default") -> Resource:
    """Import CRD — a subscription (§6.4 pub/sub).

    spec: ``job``, ``operator``, ``subscription`` ({stream: name} exact
    match or {properties: {...}} predicate), ``peId`` (the importing PE).
    The subscription broker matches Imports against Exports and excludes
    draining importers from fresh routes.
    """
    return Resource(
        kind=IMPORT, name=f"{job}-import-{op_name}", namespace=namespace,
        spec={"job": job, "operator": op_name, "subscription": subscription},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
    )


def make_consistent_region(job: str, region: str, spec: dict,
                           namespace: str = "default") -> Resource:
    """ConsistentRegion CRD — at-least-once region state (§6.5).

    spec:   ``job``, ``region``, ``interval`` (tuples/steps between
            checkpoints), ``members`` (stateful participant PE ids).
    status: ``state`` (Idle|Processing|Recovering), ``lastCommitted``
            (checkpoint id every member reported — the replay point).
    """
    return Resource(
        kind=CONSISTENT_REGION, name=cr_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, **spec},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"state": "Idle", "lastCommitted": -1},
    )


def make_metrics(job: str, namespace: str = "default") -> Resource:
    """One Metrics resource per job: the metrics plane's published rollups.

    spec is empty (there is no desired state — metrics are pure observation);
    all content lives in status, written only by the metrics coordinator:

    status: ``operators`` (op name -> latest sample + ``rate``/``peId``),
            ``regions`` (region -> {channels, backpressure, throughput,
            queueDepth, blockedPuts, stepTime, tuplesDropped, emitBatch}),
            ``updatedAt``.  ``tuplesDropped`` counts drain-timeout drops on
            scale-down; ``emitBatch`` is the mean adaptive output batch the
            region's channels currently run at.
    """
    return Resource(
        kind=METRICS, name=metrics_name(job), namespace=namespace,
        spec={"job": job},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"operators": {}, "regions": {}},
    )


def make_scaling_policy(job: str, region: str, *, min_width: int = 1,
                        max_width: int = 4, metric: str = "backpressure",
                        scale_up_at: float = 0.5, scale_down_at: float = 0.05,
                        target_per_channel: float = 0.0, step: int = 1,
                        cooldown: float = 1.0, setpoint: float = 0.5,
                        signal: str = "backpressure", kp: float = 4.0,
                        ki: float = 0.0, kd: float = 0.0,
                        hysteresis: float = 0.1,
                        integral_clamp: float = 8.0,
                        namespace: str = "default") -> Resource:
    """ScalingPolicy CRD: bounds + thresholds the autoscale conductor obeys.

    spec:   ``job``, ``region``, ``minWidth``/``maxWidth`` (clamp),
            ``metric`` — the region aggregate to scale on:

            - "backpressure": mean input-queue fill, thresholded by
              ``scaleUpAt`` / ``scaleDownAt``, stepping by ``step``;
            - "throughput": tuples/s divided by ``targetPerChannel`` gives
              the wanted width directly;
            - "pid": target tracking — drive the region aggregate named by
              ``signal`` ("backpressure", "occupancy", …) toward
              ``setpoint`` with a PID law on the error.  ``kp``/``ki``/
              ``kd`` are the gains (widths per unit error); ``hysteresis``
              is the deadband half-width around the setpoint inside which
              no action is taken (kills limit-cycle hunting); the integral
              term is conditionally accumulated (frozen while the output
              saturates at minWidth/maxWidth — anti-windup) and clamped to
              ±``integralClamp``.

            ``cooldown`` (seconds between scale actions) applies to every
            metric mode.
    status: ``lastScaleAt`` (cooldown stamp, written BEFORE the width edit
            so a conductor restart cannot double-scale), ``lastWidth``,
            ``pid`` (the controller state {error, integral, at} persisted
            on each scale action; a conductor restart between actions
            simply re-accumulates).
    """
    return Resource(
        kind=SCALING_POLICY, name=policy_name(job, region), namespace=namespace,
        spec={"job": job, "region": region, "minWidth": min_width,
              "maxWidth": max_width, "metric": metric,
              "scaleUpAt": scale_up_at, "scaleDownAt": scale_down_at,
              "targetPerChannel": target_per_channel, "step": step,
              "cooldown": cooldown, "setpoint": setpoint, "signal": signal,
              "kp": kp, "ki": ki, "kd": kd, "hysteresis": hysteresis,
              "integralClamp": integral_clamp},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"lastScaleAt": 0.0},
    )


def make_slo(job: str, *, latency_p95_ms: float | None = None,
             latency_p99_ms: float | None = None,
             loss_budget: int | None = 0,
             recovery_time_s: float | None = None,
             namespace: str = "default") -> Resource:
    """SLO CRD: the pass/fail contract a job's observability rolls up into.

    spec:   ``job``; any subset of objective dimensions (``None`` disables
            a dimension):

            - ``latencyP95Ms`` / ``latencyP99Ms``: end-to-end delivery
              latency targets, judged against the Metrics rollup's digest
              percentiles (ingest watermark -> sink);
            - ``lossBudgetTuples``: how many tuples the job may drop
              (drain-timeout / undelivered-output accounting) before the
              SLO is violated;
            - ``recoveryTimeS``: upper bound on any single pod
              restart/recovery span (failure detected -> replacement
              connected), judged against the span tracer's ``recover``
              spans.

    status: ``Met`` / ``Violated`` conditions (a complementary pair; the
            Violated reason names the failing dimensions) and ``ledger`` —
            the error-budget ledger {evaluations, violations, burnRate,
            worstP95Ms, worstP99Ms, lossSpentTuples, worstRecoveryS,
            lastVerdictAt}.  Written only through the slo coordinator.
    """
    return Resource(
        kind=SLO, name=slo_name(job), namespace=namespace,
        spec={"job": job, "latencyP95Ms": latency_p95_ms,
              "latencyP99Ms": latency_p99_ms,
              "lossBudgetTuples": loss_budget,
              "recoveryTimeS": recovery_time_s},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"ledger": {}},
    )


def make_standby_policy(job: str, *, pes: list | None = None,
                        warm_interval: float = 0.5,
                        namespace: str = "default") -> Resource:
    """StandbyPolicy CRD: which of a job's PEs get a warm standby.

    The failover conductor (``platform/failover.py``) watches this kind and
    keeps one shadow pod per protected PE placed on a *different* node
    (scheduler anti-affinity), its ring preloadable via the fabric's
    residual-carryover path and its state warmed from the latest committed
    checkpoint.  On a heartbeat-detected primary failure the standby is
    promoted in place — a single epoch bump instead of the
    delete→schedule→start→connect chain.

    spec:   ``job``; ``pes`` — PE ids to protect (``None``/empty = every
            non-source PE the job has); ``warmInterval`` — seconds between
            a holding standby's state re-warm passes.
    status: ``protected`` (pe id -> {standbyPod, node, since}), written by
            the failover conductor as standbys come up; ``promotions``
            (count of completed promotions).
    """
    return Resource(
        kind=STANDBY_POLICY, name=standby_policy_name(job),
        namespace=namespace,
        spec={"job": job, "pes": list(pes) if pes else [],
              "warmInterval": float(warm_interval)},
        labels=job_labels(job),
        owner_refs=(OwnerRef(JOB, job),),
        status={"protected": {}, "promotions": 0},
    )


def make_standby_pod(job: str, pe_id: int, pod_spec: dict, launch_count: int,
                     generation: int, namespace: str = "default") -> Resource:
    """Pod — a PE's *warm standby* incarnation (created only by the
    failover conductor).

    Identical shape to ``make_pod`` plus ``spec.standby: True`` (every
    controller that drives the restart chain skips standby pods — their
    life cycle belongs to the failover conductor) and a distinct name
    (``{job}-standby-{pe}``) so the primary's computed name stays free for
    promotion.  ``pod_spec`` carries the anti-affinity against the
    primary's per-PE label so the scheduler places the pair apart.
    """
    return Resource(
        kind=POD, name=standby_pod_name(job, pe_id), namespace=namespace,
        spec={"job": job, "peId": pe_id, "standby": True,
              "launchCount": launch_count, "jobGeneration": generation,
              **pod_spec},
        labels={**job_labels(job), "repro.ibm.com/standby": str(pe_id)},
        owner_refs=(OwnerRef(PE, pe_name(job, pe_id)),),
        status={"phase": "Pending"},
    )


#: Fault kinds the chaos conductor knows how to execute (see
#: ``src/repro/platform/chaos.py`` for the per-fault walkthroughs).
FAULT_KINDS = ("pod-kill", "kill-mid-drain", "clock-straggle",
               "partition", "node-flap", "standby-loss")


def make_fault_injection(name: str, *, fault: str, job: str | None = None,
                         target: dict | None = None, delay: float = 0.0,
                         duration: float = 0.5, seed: int = 0,
                         params: dict | None = None,
                         namespace: str = "default") -> Resource:
    """FaultInjection CRD: one declared fault, executed by the ChaosConductor.

    Chaos is injected through the platform's own declarative surfaces: the
    conductor watches this kind and fires the fault via the ``ApiClient``
    and the existing actors — never by reaching into runtime internals a
    real operator could not touch.

    spec:   ``fault`` — one of ``FAULT_KINDS``:

            - "pod-kill":        fail a Running pod (the §8 pod-recovery
                                 pathology; the recover span times it);
            - "kill-mid-drain":  arm a drain (width decrease), then kill the
                                 draining pod mid-pull — racing the
                                 ``streams/drain`` finalizer;
            - "clock-straggle":  freeze a pod's heartbeat for ``duration``
                                 seconds so the node trips ``Straggling``
                                 and the straggler monitor's timeout path
                                 is exercised;
            - "partition":       make the fabric unreachable for the target
                                 PE's endpoints for ``duration`` seconds —
                                 resolve times out, established flushes
                                 fail; senders must retry/re-buffer and the
                                 operator quarantines instead of restarting;
            - "node-flap":       delete the target node and re-add it after
                                 ``duration`` seconds (the scheduler's
                                 re-kick path re-binds evicted pods);
            - "standby-loss":    kill a protected PE's warm standby, then
                                 kill the primary *inside the re-warm
                                 window* — the degraded path: recovery must
                                 fall back to the cold restart chain.

            ``job`` — target job (None only for pure node faults);
            ``target`` — selector: ``{"peId": n}``, ``{"node": name}``, or
            ``{"random": true}`` to let the seeded RNG choose (sources are
            never chosen at random — their counters anchor loss accounting);
            ``delay`` — seconds after activation before injecting;
            ``duration`` — fault window / flap gap in seconds;
            ``seed`` — the scenario RNG seed (all chaos randomness flows
            through one ``random.Random(seed)``);
            ``params`` — per-fault extras (e.g. drain width for
            kill-mid-drain).

    status: ``phase`` (Pending|Injected|Recovered|Failed), ``seed`` (echoed
            so a red run replays deterministically), ``chosen`` (what the
            RNG picked), ``injectedAt``/``recoveredAt`` (monotonic stamps),
            ``recoverS`` (injection -> healed, from the chaos span), and the
            ``Injected`` / ``Recovered`` conditions.
    """
    if fault not in FAULT_KINDS:
        raise ValueError(f"fault injection {name!r}: unknown fault kind "
                         f"{fault!r} (want one of {FAULT_KINDS})")
    return Resource(
        kind=FAULT_INJECTION, name=name, namespace=namespace,
        spec={"fault": fault, "job": job, "target": target or {},
              "delay": float(delay), "duration": float(duration),
              "seed": int(seed), "params": params or {}},
        labels=job_labels(job) if job else {},
        status={"phase": "Pending", "seed": int(seed)},
    )


def make_node(name: str, cores: int = 16, labels: dict | None = None,
              process_isolation: bool = False) -> Resource:
    """Node — cluster substrate capacity.

    spec:   ``cores`` — schedulable CPU capacity; validated here (must be a
            positive number) so the scheduler never has to clamp a
            zero-or-negative divisor at placement time.
            ``processIsolation`` — when true, the kubelet hosts this node's
            PEs in a dedicated worker OS process (socket transport between
            processes) instead of threads of the platform process.
    status: ``pressure`` ({podsPerCore, ringFill, heartbeatLag, score,
            pods, updatedAt} — the kubelet pressure heartbeat), plus the
            ``Pressure`` / ``Straggling`` conditions.  Labels are the tags
            hostpool/node affinity match against.
    """
    if not isinstance(cores, (int, float)) or isinstance(cores, bool) \
            or cores <= 0:
        raise ValueError(f"node {name!r}: cores must be a positive number, "
                         f"got {cores!r}")
    spec: dict = {"cores": cores}
    if process_isolation:
        spec["processIsolation"] = True
    return Resource(kind=NODE, name=name, spec=spec, labels=labels or {})
