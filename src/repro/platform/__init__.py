"""Cloud-native Streams platform: the paper's architecture, end to end.

``Platform`` wires the resource store, the instance operator (controllers /
conductors / coordinators), the consistent-region operator, the cluster
substrate (scheduler + kubelets), and the data-plane fabric into a running
system.  See DESIGN.md for the paper mapping.
"""

from __future__ import annotations

import os
import tempfile
import time

from ..ckpt import CheckpointStore
from ..core import ResourceStore, Runtime, wait_for
from . import crds
from .api import ApiClient
from .autoscale import AutoscaleConductor
from .chaos import ChaosConductor, run_scenario
from .cluster import KubeletController, NodePressureMonitor
from .fabric import Fabric
from .failover import FailoverConductor
from .transport import make_transport
from .metrics import MetricsPlane
from .scheduler import NodeController, RebalanceConductor, SchedulerController
from .slo import SLOConductor
from .tracing import SpanTracer
from .operator import (
    ConsistentRegionController,
    ConsistentRegionOperator,
    ExportController,
    ImportController,
    JobConductor,
    JobController,
    ParallelRegionController,
    PEController,
    PodConductor,
    PodController,
    RestFacade,
    StragglerMonitor,
    SubscriptionBroker,
)
from .pipeline import plan_job


class Platform:
    """One namespace's worth of cloud-native Streams."""

    def __init__(self, namespace: str = "default", num_nodes: int = 4,
                 cores_per_node: int = 8, ckpt_root: str | None = None,
                 wal_path: str | None = None, dns_delay: float = 0.0,
                 threaded: bool = True, with_cluster: bool = True,
                 store: ResourceStore | None = None,
                 scheduler_profile: str = "pressure",
                 rebalance: bool = False, cpu_model: bool = False,
                 pressure_interval: float = 0.5,
                 transport: str | None = None,
                 process_isolation: bool = False,
                 pod_start_delay: float = 0.0):
        self.namespace = namespace
        self.store = store or ResourceStore(wal_path=wal_path)
        # the span tracer IS the causal trace (tracing.py grows it): flat
        # records for chain assertions, parented timed spans for the
        # observability plane
        self.trace = SpanTracer()
        # transport seam: ``transport="socket"`` loops every endpoint's
        # tuple batches through the local socket hub even in-process (the
        # backend-parametrized test matrix); ``process_isolation=True``
        # marks every substrate node processIsolation so its PEs run in
        # per-node worker processes (the scale-out path)
        self._owned_transport = make_transport(transport) if transport else None
        self.process_isolation = process_isolation
        self.fabric = Fabric(dns_delay=dns_delay,
                             transport=self._owned_transport)
        self.ckpt = CheckpointStore(ckpt_root or tempfile.mkdtemp(prefix="repro-ckpt-"))

        # the typed declarative API: one coordinator per kind, every
        # spec/status write routed through it (single-writer by construction)
        self.api = ApiClient(self.store, namespace, trace=self.trace)
        coords = self.api.coords
        self.coords = coords
        self.rest = RestFacade(self.store, coords["pod"], self.ckpt, namespace,
                               trace=self.trace)

        # --- instance operator actors
        self.job_controller = JobController(self.store, namespace, coords,
                                            self.trace, fabric=self.fabric,
                                            api=self.api)
        self.pe_controller = PEController(self.store, namespace, coords, self.trace)
        self.pod_controller = PodController(self.store, namespace, coords,
                                            self.trace, api=self.api)
        self.pr_controller = ParallelRegionController(self.store, namespace,
                                                      coords, self.trace)
        self.import_controller = ImportController(self.store, namespace, self.trace)
        self.export_controller = ExportController(self.store, namespace, self.trace)
        self.cr_controller = ConsistentRegionController(self.store, namespace,
                                                        self.trace)
        self.pod_conductor = PodConductor(self.store, namespace, coords,
                                          self.trace, api=self.api)
        self.job_conductor = JobConductor(self.store, namespace, coords,
                                          self.trace, api=self.api)
        self.broker = SubscriptionBroker(self.store, namespace, self.fabric,
                                         self.trace)
        self.cr_operator = ConsistentRegionOperator(self.store, namespace, coords,
                                                    self.fabric, self.ckpt,
                                                    self.trace)
        self.rest.cr_operator = self.cr_operator
        self.rest.broker = self.broker
        self.straggler_monitor = StragglerMonitor(self.store, namespace,
                                                  coords["pod"], self.trace)
        # metrics plane + elastic autoscaling (the load -> width control loop)
        self.metrics_plane = MetricsPlane(self.store, namespace, coords,
                                          self.trace, api=self.api)
        self.autoscaler = AutoscaleConductor(self.store, namespace, coords,
                                             self.trace, api=self.api)
        # SLO verdict plane: judges Metrics rollups + recovery spans into
        # Met/Violated conditions and an error-budget ledger
        self.slo_conductor = SLOConductor(self.store, namespace, coords,
                                          self.trace, api=self.api)

        # conductor registration (paper Fig. 4 observation matrix)
        self.pe_controller.add_listener(self.pod_conductor)
        self.pe_controller.add_listener(self.job_conductor)
        self.pod_controller.add_listener(self.pod_conductor)
        self.pod_controller.add_listener(self.job_conductor)
        self.pod_controller.add_listener(self.cr_operator)
        self.pod_controller.add_listener(self.metrics_plane)
        self.job_controller.add_listener(self.job_conductor)
        # Job deletions prune the metrics plane's per-job ledgers and the
        # SLO conductor's throttle map
        self.job_controller.add_listener(self.metrics_plane)
        self.job_controller.add_listener(self.slo_conductor)
        self.import_controller.add_listener(self.broker)
        self.export_controller.add_listener(self.broker)
        self.cr_controller.add_listener(self.cr_operator)
        self.pr_controller.add_listener(self.autoscaler)

        # ConfigMap/Service events reach conductors through dedicated
        # lightweight controllers (a controller tracks exactly one kind).
        from ..core import Controller

        self.cm_controller = Controller(self.store, crds.CONFIG_MAP, namespace,
                                        "configmap-controller", self.trace)
        self.svc_controller = Controller(self.store, crds.SERVICE, namespace,
                                         "service-controller", self.trace)
        self.cm_controller.add_listener(self.pod_conductor)
        self.cm_controller.add_listener(self.job_conductor)
        self.svc_controller.add_listener(self.pod_conductor)
        self.svc_controller.add_listener(self.job_conductor)

        # Metrics / ScalingPolicy events reach the autoscale conductor the
        # same way: one lightweight controller per kind.
        self.metrics_controller = Controller(self.store, crds.METRICS,
                                             namespace, "metrics-controller",
                                             self.trace)
        self.policy_controller = Controller(self.store, crds.SCALING_POLICY,
                                            namespace,
                                            "scalingpolicy-controller",
                                            self.trace)
        self.metrics_controller.add_listener(self.autoscaler)
        self.policy_controller.add_listener(self.autoscaler)

        # SLO events reach the verdict plane the same way; Metrics updates
        # re-judge standing SLOs at the evaluation cadence.
        self.slo_controller = Controller(self.store, crds.SLO, namespace,
                                         "slo-controller", self.trace)
        self.slo_controller.add_listener(self.slo_conductor)
        self.metrics_controller.add_listener(self.slo_conductor)

        controllers = [
            self.job_controller, self.pe_controller, self.pod_controller,
            self.pr_controller, self.import_controller, self.export_controller,
            self.cr_controller, self.cm_controller, self.svc_controller,
            self.metrics_controller, self.policy_controller,
            self.slo_controller,
        ]

        # --- cluster substrate (Kubernetes's half): plugin scheduler fed by
        # the node pressure plane, kubelets, and (opt-in) the rebalance
        # conductor that migrates PEs off sustained-hot nodes
        self.kubelet = None
        self.pressure_monitor = None
        self.rebalancer = None
        if with_cluster:
            self.scheduler = SchedulerController(self.store, coords["pod"],
                                                 namespace, self.trace,
                                                 profile=scheduler_profile)
            self.kubelet = KubeletController(self.store, coords["pod"],
                                             self.fabric, self.rest, namespace,
                                             self.trace, cpu_model=cpu_model,
                                             start_delay=pod_start_delay)
            self.node_controller = NodeController(self.store, namespace,
                                                  self.trace,
                                                  scheduler=self.scheduler)
            self.pressure_monitor = NodePressureMonitor(
                self.store, namespace, coords, self.trace, api=self.api,
                interval=pressure_interval)
            self.rebalancer = RebalanceConductor(self.store, namespace, coords,
                                                 self.trace, api=self.api,
                                                 enabled=rebalance)
            self.node_controller.add_listener(self.rebalancer)
            self.pod_controller.add_listener(self.rebalancer)
            controllers += [self.scheduler, self.kubelet, self.node_controller]
            for i in range(num_nodes):
                self.api.nodes.create(crds.make_node(
                    f"node{i}", cores_per_node,
                    process_isolation=process_isolation))

        # --- recovery plane: the failover conductor keeps warm standbys
        # converged to StandbyPolicy records, promotes one on primary
        # failure, and owns the post-commit checkpoint sweep (it is wired
        # even without policies: every CR commit still needs sweeping)
        self.failover = FailoverConductor(
            self.store, namespace, coords, self.trace, api=self.api,
            kubelet=self.kubelet, ckpt=self.ckpt)
        self.standby_controller = Controller(self.store, crds.STANDBY_POLICY,
                                             namespace,
                                             "standbypolicy-controller",
                                             self.trace)
        self.standby_controller.add_listener(self.failover)
        self.pod_controller.add_listener(self.failover)
        self.cr_controller.add_listener(self.failover)
        controllers.append(self.standby_controller)

        # --- chaos plane: FaultInjection records reach the ChaosConductor
        # through a dedicated lightweight controller (same pattern as the
        # metrics/SLO planes); the conductor executes faults through the
        # typed API + the very actors above — no side doors
        self.chaos = ChaosConductor(
            self.store, namespace, coords, self.trace, api=self.api,
            fabric=self.fabric, kubelet=self.kubelet, rest=self.rest,
            scheduler=getattr(self, "scheduler", None),
            straggler=self.straggler_monitor)
        self.fault_controller = Controller(self.store, crds.FAULT_INJECTION,
                                           namespace,
                                           "faultinjection-controller",
                                           self.trace)
        self.fault_controller.add_listener(self.chaos)
        controllers.append(self.fault_controller)

        self.runtime = Runtime(self.store, threaded=threaded)
        for c in controllers:
            self.runtime.register(c)
        if threaded and self.pressure_monitor is not None:
            self.pressure_monitor.start()

    # ------------------------------------------------------------- actions

    def submit(self, name: str, spec: dict):
        return self.api.jobs.create(crds.make_job(name, spec, self.namespace))

    def delete_job(self, name: str) -> None:
        """Tear a job down.  The default is foreground cascade deletion
        driven by owner-reference finalizers (mid-drain PEs hold their
        branch open until their ``streams/drain`` finalizer clears); a job
        submitted with ``gcMode: "manual"`` keeps the §8 bulk label sweep."""
        job = self.api.jobs.try_get(name)
        gc_mode = (job.spec.get("gcMode", "foreground")
                   if job is not None else "foreground")
        self.api.jobs.delete(
            name,
            propagation="orphan" if gc_mode == "manual" else "foreground")

    def set_width(self, job: str, region: str, width: int) -> None:
        """kubectl edit parallelregion ... (paper §6.3) — through the pr
        coordinator: no spec write bypasses the single writer."""
        from ..core import NotFoundError

        out = self.api.parallel_regions.patch(crds.pr_name(job, region),
                                              {"width": width},
                                              requester="user")
        if out is None:
            raise NotFoundError(
                f"ParallelRegion {crds.pr_name(job, region)} not found")

    def kill_pod(self, job: str, pe_id: int) -> bool:
        assert self.kubelet is not None
        return self.kubelet.kill_pod(crds.pod_name(job, pe_id))

    def add_node(self, name: str, cores: int = 8,
                 labels: dict | None = None,
                 process_isolation: bool | None = None):
        """Grow the substrate at runtime (kubectl create node ...): the
        node controller re-kicks unschedulable pods onto the new capacity,
        and — with rebalancing enabled — the rebalance conductor starts
        migrating PEs off any sustained-hot node toward it."""
        if process_isolation is None:
            process_isolation = self.process_isolation
        return self.api.nodes.create(crds.make_node(
            name, cores, labels, process_isolation=process_isolation))

    def node_pressure(self, name: str) -> dict:
        """The pressure plane's latest heartbeat for one node."""
        node = self.store.try_get(crds.NODE, name)
        return dict(node.status.get("pressure") or {}) if node else {}

    def set_scaling_policy(self, job: str, region: str, **kw):
        """kubectl apply scalingpolicy ... (server-side apply)."""
        res = crds.make_scaling_policy(job, region, namespace=self.namespace,
                                       **kw)
        return self.api.scaling_policies.apply(res, requester="user")

    def delete_scaling_policy(self, job: str, region: str) -> bool:
        return self.api.scaling_policies.delete(crds.policy_name(job, region))

    def set_standby_policy(self, job: str, **kw):
        """kubectl apply standbypolicy ... — protect a job's PEs with warm
        standbys (see ``make_standby_policy``; the failover conductor
        converges shadow pods and promotes one on primary failure)."""
        res = crds.make_standby_policy(job, namespace=self.namespace, **kw)
        return self.api.standby_policies.apply(res, requester="user")

    def delete_standby_policy(self, job: str) -> bool:
        return self.api.standby_policies.delete(crds.standby_policy_name(job))

    def set_slo(self, job: str, **kw):
        """kubectl apply slo ... — declare the job's pass/fail contract
        (latency targets / loss budget / recovery bound; see ``make_slo``)."""
        res = crds.make_slo(job, namespace=self.namespace, **kw)
        return self.api.slos.apply(res, requester="user")

    def inject_fault(self, fault: str, job: str | None = None, **kw):
        """kubectl create faultinjection ... — fire-and-forget chaos: the
        ChaosConductor picks the record up and executes it.  The record is
        NOT auto-deleted; prefer ``run_scenario`` for scripted runs."""
        tag = kw.pop("tag", fault)
        name = kw.pop("name", crds.fault_name(job or "cluster", tag))
        return self.api.fault_injections.create(crds.make_fault_injection(
            name, fault=fault, job=job, namespace=self.namespace, **kw))

    def run_scenario(self, **kw) -> dict:
        """One chaos scenario end to end (inject -> recover -> verdict
        evidence -> record cleanup); see ``chaos.run_scenario``."""
        return run_scenario(self, **kw)

    def slo_status(self, job: str) -> dict:
        """The SLO conductor's published verdict + error-budget ledger."""
        res = self.store.try_get(crds.SLO, crds.slo_name(job), self.namespace)
        return dict(res.status) if res else {}

    def metrics_text(self) -> str:
        """Prometheus-style text exposition (the ``/metrics`` scrape)."""
        return self.rest.metrics_text()

    def export_trace(self, path: str) -> str:
        """Write the span ring as Chrome trace-event JSON."""
        return self.trace.export_chrome(path)

    def region_width(self, job: str, region: str) -> int:
        pr = self.store.try_get(crds.PARALLEL_REGION, crds.pr_name(job, region),
                                self.namespace)
        return pr.spec.get("width", 0) if pr else 0

    def job_metrics(self, job: str) -> dict:
        """The metrics plane's published rollup for one job."""
        res = self.store.try_get(crds.METRICS, crds.metrics_name(job),
                                 self.namespace)
        return dict(res.status) if res else {}

    # -------------------------------------------------------------- waits

    def job_status(self, name: str) -> dict:
        res = self.store.try_get(crds.JOB, name, self.namespace)
        return dict(res.status) if res else {}

    def wait_submitted(self, name: str, timeout: float = 30.0) -> bool:
        """Watch-based wait on the Job's ``Submitted`` condition."""
        return self.api.jobs.wait_for_condition(name, crds.COND_SUBMITTED,
                                                timeout=timeout)

    def wait_full_health(self, name: str, timeout: float = 60.0) -> bool:
        """Watch-based wait on the Job's ``FullHealth`` condition."""
        return self.api.jobs.wait_for_condition(name, crds.COND_FULL_HEALTH,
                                                timeout=timeout)

    def wait_terminated(self, name: str, timeout: float = 60.0) -> bool:
        """Watch-based wait until no resource labeled with the job remains
        (event-driven: re-checks on the job's own deletions instead of
        spin-polling)."""
        labels = crds.job_labels(name)
        sub = self.store.watch(namespace=self.namespace, replay=False)
        try:
            def gone():
                return not self.store.list(namespace=self.namespace,
                                           label_selector=labels)

            if gone():
                return True
            deadline = time.monotonic() + timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return gone()
                ev = sub.take(timeout=remaining)
                if ev is None or ev.type.value != "DELETED":
                    continue
                # only this job's deletions can empty its label set — skip
                # the O(store) list for unrelated events
                if all(ev.resource.labels.get(k) == v
                       for k, v in labels.items()) and gone():
                    return True
        finally:
            self.store.unwatch(sub)

    def wait_cr_committed(self, job: str, region: str, step: int,
                          timeout: float = 120.0) -> bool:
        def ok():
            st = self.rest.get_cr_state(job, region)
            return st is not None and st.get("lastCommitted", -1) >= step
        return wait_for(ok, timeout)

    def pods(self, job: str) -> list:
        return self.store.list(crds.POD, self.namespace, crds.job_labels(job))

    def metrics(self, job: str) -> dict:
        out = {}
        for pod in self.pods(job):
            if pod.status.get("metrics"):
                out[pod.spec["peId"]] = pod.status["metrics"]
        return out

    def shutdown(self) -> None:
        self.straggler_monitor.stop()
        if self.pressure_monitor is not None:
            self.pressure_monitor.stop()
        if self.kubelet is not None:
            self.kubelet.stop_all()
        self.runtime.stop()
        self.store.close()
        if self._owned_transport is not None:
            self._owned_transport.close()


__all__ = ["Platform", "crds", "plan_job"]
