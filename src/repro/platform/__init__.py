"""Cloud-native Streams platform: the paper's architecture, end to end.

``Platform`` wires the resource store, the instance operator (controllers /
conductors / coordinators), the consistent-region operator, the cluster
substrate (scheduler + kubelets), and the data-plane fabric into a running
system.  See DESIGN.md for the paper mapping.
"""

from __future__ import annotations

import os
import tempfile
import time

from ..ckpt import CheckpointStore
from ..core import CausalTrace, Coordinator, ResourceStore, Runtime, wait_for
from . import crds
from .autoscale import AutoscaleConductor
from .cluster import KubeletController, SchedulerController
from .fabric import Fabric
from .metrics import MetricsPlane
from .operator import (
    ConsistentRegionController,
    ConsistentRegionOperator,
    ExportController,
    ImportController,
    JobConductor,
    JobController,
    ParallelRegionController,
    PEController,
    PodConductor,
    PodController,
    RestFacade,
    StragglerMonitor,
    SubscriptionBroker,
)
from .pipeline import plan_job


class Platform:
    """One namespace's worth of cloud-native Streams."""

    def __init__(self, namespace: str = "default", num_nodes: int = 4,
                 cores_per_node: int = 8, ckpt_root: str | None = None,
                 wal_path: str | None = None, dns_delay: float = 0.0,
                 threaded: bool = True, with_cluster: bool = True,
                 store: ResourceStore | None = None):
        self.namespace = namespace
        self.store = store or ResourceStore(wal_path=wal_path)
        self.trace = CausalTrace()
        self.fabric = Fabric(dns_delay=dns_delay)
        self.ckpt = CheckpointStore(ckpt_root or tempfile.mkdtemp(prefix="repro-ckpt-"))

        coords = {
            "job": Coordinator(self.store, crds.JOB, namespace, trace=self.trace),
            "pe": Coordinator(self.store, crds.PE, namespace, trace=self.trace),
            "pod": Coordinator(self.store, crds.POD, namespace, trace=self.trace),
            "cr": Coordinator(self.store, crds.CONSISTENT_REGION, namespace,
                              trace=self.trace),
            "pr": Coordinator(self.store, crds.PARALLEL_REGION, namespace,
                              trace=self.trace),
            "metrics": Coordinator(self.store, crds.METRICS, namespace,
                                   trace=self.trace),
            "policy": Coordinator(self.store, crds.SCALING_POLICY, namespace,
                                  trace=self.trace),
        }
        self.coords = coords
        self.rest = RestFacade(self.store, coords["pod"], self.ckpt, namespace)

        # --- instance operator actors
        self.job_controller = JobController(self.store, namespace, coords,
                                            self.trace, fabric=self.fabric)
        self.pe_controller = PEController(self.store, namespace, coords, self.trace)
        self.pod_controller = PodController(self.store, namespace, coords, self.trace)
        self.pr_controller = ParallelRegionController(self.store, namespace,
                                                      coords, self.trace)
        self.import_controller = ImportController(self.store, namespace, self.trace)
        self.export_controller = ExportController(self.store, namespace, self.trace)
        self.cr_controller = ConsistentRegionController(self.store, namespace,
                                                        self.trace)
        self.pod_conductor = PodConductor(self.store, namespace, coords, self.trace)
        self.job_conductor = JobConductor(self.store, namespace, coords, self.trace)
        self.broker = SubscriptionBroker(self.store, namespace, self.fabric,
                                         self.trace)
        self.cr_operator = ConsistentRegionOperator(self.store, namespace, coords,
                                                    self.fabric, self.ckpt,
                                                    self.trace)
        self.rest.cr_operator = self.cr_operator
        self.rest.broker = self.broker
        self.straggler_monitor = StragglerMonitor(self.store, namespace,
                                                  coords["pod"], self.trace)
        # metrics plane + elastic autoscaling (the load -> width control loop)
        self.metrics_plane = MetricsPlane(self.store, namespace, coords,
                                          self.trace)
        self.autoscaler = AutoscaleConductor(self.store, namespace, coords,
                                             self.trace)

        # conductor registration (paper Fig. 4 observation matrix)
        self.pe_controller.add_listener(self.pod_conductor)
        self.pe_controller.add_listener(self.job_conductor)
        self.pod_controller.add_listener(self.pod_conductor)
        self.pod_controller.add_listener(self.job_conductor)
        self.pod_controller.add_listener(self.cr_operator)
        self.pod_controller.add_listener(self.metrics_plane)
        self.job_controller.add_listener(self.job_conductor)
        self.import_controller.add_listener(self.broker)
        self.export_controller.add_listener(self.broker)
        self.cr_controller.add_listener(self.cr_operator)
        self.pr_controller.add_listener(self.autoscaler)

        # ConfigMap/Service events reach conductors through dedicated
        # lightweight controllers (a controller tracks exactly one kind).
        from ..core import Controller

        self.cm_controller = Controller(self.store, crds.CONFIG_MAP, namespace,
                                        "configmap-controller", self.trace)
        self.svc_controller = Controller(self.store, crds.SERVICE, namespace,
                                         "service-controller", self.trace)
        self.cm_controller.add_listener(self.pod_conductor)
        self.cm_controller.add_listener(self.job_conductor)
        self.svc_controller.add_listener(self.pod_conductor)
        self.svc_controller.add_listener(self.job_conductor)

        # Metrics / ScalingPolicy events reach the autoscale conductor the
        # same way: one lightweight controller per kind.
        self.metrics_controller = Controller(self.store, crds.METRICS,
                                             namespace, "metrics-controller",
                                             self.trace)
        self.policy_controller = Controller(self.store, crds.SCALING_POLICY,
                                            namespace,
                                            "scalingpolicy-controller",
                                            self.trace)
        self.metrics_controller.add_listener(self.autoscaler)
        self.policy_controller.add_listener(self.autoscaler)

        controllers = [
            self.job_controller, self.pe_controller, self.pod_controller,
            self.pr_controller, self.import_controller, self.export_controller,
            self.cr_controller, self.cm_controller, self.svc_controller,
            self.metrics_controller, self.policy_controller,
        ]

        # --- cluster substrate (Kubernetes's half)
        self.kubelet = None
        if with_cluster:
            self.scheduler = SchedulerController(self.store, coords["pod"],
                                                 namespace, self.trace)
            self.kubelet = KubeletController(self.store, coords["pod"],
                                             self.fabric, self.rest, namespace,
                                             self.trace)
            controllers += [self.scheduler, self.kubelet]
            for i in range(num_nodes):
                self.store.create(crds.make_node(f"node{i}", cores_per_node))

        self.runtime = Runtime(self.store, threaded=threaded)
        for c in controllers:
            self.runtime.register(c)

    # ------------------------------------------------------------- actions

    def submit(self, name: str, spec: dict):
        return self.store.create(crds.make_job(name, spec, self.namespace))

    def delete_job(self, name: str) -> None:
        self.store.try_delete(crds.JOB, name, self.namespace)

    def set_width(self, job: str, region: str, width: int) -> None:
        """kubectl edit parallelregion ... (paper §6.3)."""

        def edit(res):
            res.spec["width"] = width

        self.store.update(crds.PARALLEL_REGION, crds.pr_name(job, region), edit,
                          namespace=self.namespace)

    def kill_pod(self, job: str, pe_id: int) -> bool:
        assert self.kubelet is not None
        return self.kubelet.kill_pod(crds.pod_name(job, pe_id))

    def set_scaling_policy(self, job: str, region: str, **kw):
        """kubectl apply scalingpolicy ... (create-or-replace)."""
        res = crds.make_scaling_policy(job, region, namespace=self.namespace,
                                       **kw)
        if self.store.exists(crds.SCALING_POLICY, res.name, self.namespace):
            def edit(cur, spec=res.spec):
                cur.spec.update(spec)
            return self.store.update(crds.SCALING_POLICY, res.name, edit,
                                     namespace=self.namespace)
        return self.store.create(res)

    def delete_scaling_policy(self, job: str, region: str) -> bool:
        return self.store.try_delete(crds.SCALING_POLICY,
                                     crds.policy_name(job, region),
                                     self.namespace)

    def region_width(self, job: str, region: str) -> int:
        pr = self.store.try_get(crds.PARALLEL_REGION, crds.pr_name(job, region),
                                self.namespace)
        return pr.spec.get("width", 0) if pr else 0

    def job_metrics(self, job: str) -> dict:
        """The metrics plane's published rollup for one job."""
        res = self.store.try_get(crds.METRICS, crds.metrics_name(job),
                                 self.namespace)
        return dict(res.status) if res else {}

    # -------------------------------------------------------------- waits

    def job_status(self, name: str) -> dict:
        res = self.store.try_get(crds.JOB, name, self.namespace)
        return dict(res.status) if res else {}

    def wait_submitted(self, name: str, timeout: float = 30.0) -> bool:
        return wait_for(lambda: self.job_status(name).get("state") == "Submitted",
                        timeout)

    def wait_full_health(self, name: str, timeout: float = 60.0) -> bool:
        return wait_for(lambda: self.job_status(name).get("fullHealth"), timeout)

    def wait_terminated(self, name: str, timeout: float = 60.0) -> bool:
        def gone():
            left = self.store.list(namespace=self.namespace,
                                   label_selector=crds.job_labels(name))
            return not left
        return wait_for(gone, timeout)

    def wait_cr_committed(self, job: str, region: str, step: int,
                          timeout: float = 120.0) -> bool:
        def ok():
            st = self.rest.get_cr_state(job, region)
            return st is not None and st.get("lastCommitted", -1) >= step
        return wait_for(ok, timeout)

    def pods(self, job: str) -> list:
        return self.store.list(crds.POD, self.namespace, crds.job_labels(job))

    def metrics(self, job: str) -> dict:
        out = {}
        for pod in self.pods(job):
            if pod.status.get("metrics"):
                out[pod.spec["peId"]] = pod.status["metrics"]
        return out

    def shutdown(self) -> None:
        self.straggler_monitor.stop()
        if self.kubelet is not None:
            self.kubelet.stop_all()
        self.runtime.stop()
        self.store.close()


__all__ = ["Platform", "crds", "plan_job"]
