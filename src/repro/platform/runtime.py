"""PE runtimes: the user code executing inside pods.

Each pod runs one PE (the paper's fundamental design decision, §5.1).  The
runtime implements the paper's PE translation layer: it publishes its input
ports to the fabric ("creates socket receivers + publishes port labels"),
resolves peer ports by *computed* names (no stored port labels — §5.2 name
resolution), reports connectivity/liveness/metrics through the REST facade
(§5.2 message bus), and participates in the consistent-region protocol
(§6.5).

Tuple transport hot path (the Fig. 8 bottleneck): emission is *buffered and
batched*.  ``_emit`` appends to a per-peer output buffer; a buffer is
flushed — one ``EndpointCache`` lookup + one ``put_many`` lock crossing for
the whole batch — when it reaches ``emit_batch`` tuples, when the oldest
buffered tuple is older than the ``emit_linger`` deadline, or on
checkpoint / drain / shutdown (so consistent-region and scale-down
semantics are unchanged: nothing a checkpoint covers is ever stranded in a
buffer).  Peer endpoints are resolved through the fabric's epoch-stamped
``EndpointCache`` — zero re-resolves while no binding moves — and pub/sub
routes (§6.4) are cached against the subscription broker's epoch instead
of being re-read from the REST facade per send.  The pull loops mirror
this: ``get_many`` moves a batch per lock crossing.

Operator kinds:
- source / pipe / sink: the paper's streaming operators (tuple dataflow);
- trainer / reducer: a data-parallel JAX training shard + metric combine —
  gradient all-reduce goes over the fabric's CollectiveGroup ("ICI");
- router / server: replicated serving.

A PE with multiple fused operators executes them as an in-process chain
(operator fusion, §6.1 step 4).
"""

from __future__ import annotations

import threading
import time
import traceback

import jax
import numpy as np

from ..data.stream import StreamSource
from .fabric import EndpointCache, EpochAborted, Fabric, ShutDown, TupleQueue


class PERuntime(threading.Thread):
    def __init__(self, *, job: str, pe_id: int, metadata: dict, fabric: Fabric,
                 rest, launch_count: int, stop_event: threading.Event,
                 on_exit=None):
        super().__init__(name=f"pe-{job}-{pe_id}", daemon=True)
        self.job = job
        self.pe_id = pe_id
        self.meta = metadata
        self.fabric = fabric
        self.rest = rest
        self.launch_count = launch_count
        self.stop_event = stop_event
        self.on_exit = on_exit
        self.in_queues: dict = {}
        self.out_targets: dict = {}  # portId -> list[(peer pe, peer port)]
        self.crashed = False
        self.counts = {"in": 0, "out": 0, "routed": 0}
        self._last_load_report = 0.0
        # batched emission state (flush policy: size + linger + barriers)
        cfg0 = (self.meta.get("operators") or [{}])[0].get("config", {})
        self.emit_batch = max(1, int(cfg0.get("emit_batch", 64)))
        self.emit_linger = float(cfg0.get("emit_linger", 0.002))
        self.endpoints = EndpointCache(fabric)
        self._out_buf: dict = {}  # (peer pe, peer port) -> list[tuple]
        self._route_buf: list = []
        self._buf_since: float | None = None  # oldest unflushed append
        self._route_cache: list = []
        self._route_key = None  # (broker epoch, fabric epoch) of the cache
        self._routes_exist = False  # cheap per-tuple flag; see _refresh_routes

    # ------------------------------------------------------------- plumbing

    def _connect(self) -> None:
        for port in self.meta.get("inputs", []):
            q = TupleQueue()
            self.in_queues[port["portId"]] = q
            self.fabric.publish(self.job, self.pe_id, port["portId"], q)
        for port in self.meta.get("outputs", []):
            # verify peers resolve (connection established), but keep the
            # *names* — sends go through the epoch-stamped EndpointCache so
            # a restarted peer's fresh endpoint is picked up on the next
            # epoch move (paper: PEs re-establish connections after
            # failures; names are computed, never stale)
            for peer_pe, peer_port in port["to"]:
                self.fabric.resolve(self.job, peer_pe, peer_port)
            self.out_targets[port["portId"]] = list(map(tuple, port["to"]))
        self._refresh_routes()  # notice routes matched before we started
        self.rest.notify_connected(self.job, self.pe_id)

    # ------------------------------------------------- batched emission

    def _refresh_routes(self) -> list:
        """Pub/sub route queues (Import/Export, §6.4), cached against the
        broker epoch (route set changes) and the fabric epoch (an importer
        PE restarted, so its resolved queue reference moved).  Called at
        flush/batch granularity — the per-tuple path only reads the
        ``_routes_exist`` flag this maintains."""
        key = (self.rest.routes_epoch(), self.fabric.epoch)
        if key != self._route_key:
            op0 = self.meta["operators"][0]
            self._route_cache = self.rest.get_routes(self.job, op0["name"])
            self._route_key = key
            self._routes_exist = bool(self._route_cache)
        return self._route_cache

    def _emit(self, port_id: int, item, partition: int | None = None) -> None:
        """Buffer ``item`` toward its target peer(s); out-tuple accounting
        happens per copy at flush time, on successful handoff to the peer
        queue (a broadcast to N peers counts N)."""
        targets = self.out_targets.get(port_id, ())
        if targets:
            if partition is not None:  # split into a parallel region
                self._buffer(targets[partition % len(targets)], item)
            else:
                for t in targets:
                    self._buffer(t, item)
        elif not self._routes_exist:
            # export-only emitter with no matched routes: no flush ever
            # runs, so probing per emit is the only way to notice the
            # first match (and this PE does no other transport work)
            self._refresh_routes()
        if self._routes_exist:
            self._route_buf.append(item)
            if self._buf_since is None:
                self._buf_since = time.monotonic()
            if len(self._route_buf) >= self.emit_batch:
                self._flush_routes()
                self._reset_linger_if_empty()

    def _buffer(self, peer: tuple, item) -> None:
        buf = self._out_buf.get(peer)
        if buf is None:
            buf = self._out_buf[peer] = []
        buf.append(item)
        if self._buf_since is None:
            self._buf_since = time.monotonic()
        if len(buf) >= self.emit_batch:
            self._flush_peer(peer, buf)
            # refresh here too: under sustained load size flushes pre-empt
            # the linger flush, and this must still notice new routes
            self._refresh_routes()
            self._reset_linger_if_empty()

    def _reset_linger_if_empty(self) -> None:
        """After a size-triggered flush the linger clock must not keep the
        drained batch's start time: the next lone tuple would inherit it and
        flush almost immediately, defeating the batching."""
        if not self._route_buf and all(not b for b in self._out_buf.values()):
            self._buf_since = None

    def _flush_peer(self, peer: tuple, buf: list) -> None:
        if not buf:
            return
        items = buf[:]
        del buf[:]
        try:
            q = self.endpoints.get(self.job, peer[0], peer[1], timeout=0.2)
            q.put_many(items,
                       timeout=0.2 if self.stop_event.is_set() else 2.0)
            # counted on successful handoff so the metrics plane's
            # throughput rollup (what the autoscaler scales on) tracks
            # delivery, not buffering toward a possibly-dead peer
            self.counts["out"] += len(items)
        except ShutDown:
            # peer retired mid-put: any admitted prefix sits in a closed
            # queue no consumer will drain — that is not delivery
            pass
        except Exception as e:
            # peer down/restarting: outside a consistent region streams are
            # best-effort; within one, replay-from-checkpoint repairs this.
            # A timed-out put to a live peer still admitted a prefix that
            # is genuinely in flight — count it.
            self.counts["out"] += getattr(e, "admitted", 0)

    def _flush_routes(self) -> None:
        if not self._route_buf:
            return
        items = self._route_buf
        self._route_buf = []
        for q in self._refresh_routes():
            try:
                q.put_many(items, timeout=1.0)
                self.counts["routed"] += len(items)
            except ShutDown:
                pass  # importer retired: its queue is closed, not slow
            except Exception as e:
                self.counts["routed"] += getattr(e, "admitted", 0)

    def _flush_all(self) -> None:
        self._refresh_routes()  # flush moments also notice new routes
        for peer, buf in self._out_buf.items():
            self._flush_peer(peer, buf)
        self._flush_routes()
        self._buf_since = None

    def _maybe_flush(self, now: float | None = None) -> None:
        """Linger deadline: flush everything once the oldest buffered tuple
        has waited ``emit_linger`` seconds."""
        if self._buf_since is None:
            return
        now = time.monotonic() if now is None else now
        if now - self._buf_since >= self.emit_linger:
            self._flush_all()

    def _pull_timeout(self, idle: float = 0.1) -> float:
        """Input-pull block time, capped by the linger deadline so buffered
        output is flushed on time even when no input arrives."""
        if self._buf_since is None:
            return idle
        remaining = self._buf_since + self.emit_linger - time.monotonic()
        return min(idle, max(remaining, 0.0))

    # ------------------------------------------------------------- metrics

    def load_metrics(self, extra: dict | None = None) -> dict:
        """The per-PE load sample the metrics plane aggregates (§5.2 metrics
        reporting, extended with the queue-depth/backpressure signals the
        autoscale conductor scales on)."""
        op = self.meta["operators"][0]
        stats = [q.stats() for q in self.in_queues.values()]
        depth = sum(s["depth"] for s in stats)
        cap = sum(s["capacity"] for s in stats)
        blocked = sum(s["blockedPuts"] for s in stats)
        batches = sum(s["getBatches"] for s in stats)
        dequeued = sum(s["dequeued"] for s in stats)
        cache = self.endpoints.stats()
        sample = {
            "operator": op["name"], "kind": op["kind"],
            "region": op.get("region"), "channel": op.get("channel", -1),
            "tuplesIn": self.counts["in"], "tuplesOut": self.counts["out"],
            "tuplesRouted": self.counts["routed"],
            "queueDepth": depth, "queueCapacity": cap,
            "backpressure": depth / cap if cap else 0.0,
            "blockedPuts": blocked,
            "queueHighWatermark": sum(s["highWatermark"] for s in stats),
            "avgPullBatch": dequeued / batches if batches else 0.0,
            "resolveHits": cache["hits"], "resolveMisses": cache["misses"],
            "resolveInvalidations": cache["invalidations"],
            "monotonic": time.monotonic(),
        }
        if extra:
            sample.update(extra)
        return sample

    def _report_load(self, extra: dict | None = None,
                     interval: float = 0.2) -> None:
        now = time.monotonic()
        if now - self._last_load_report < interval:
            return
        self._last_load_report = now
        self.rest.report_metrics(self.job, self.pe_id,
                                 self.load_metrics(extra))

    # ---------------------------------------------------------------- body

    def run(self) -> None:
        try:
            self._connect()
            kinds = [o["kind"] for o in self.meta["operators"]]
            if "trainer" in kinds:
                self._run_trainer()
            elif "source" in kinds:
                self._run_source()
            elif "reducer" in kinds:
                self._run_reducer()
            elif "server" in kinds or "router" in kinds:
                self._run_chain()  # same pull-transform-push loop
            elif "sink" in kinds:
                self._run_chain()
            else:
                self._run_chain()
        except Exception:  # noqa: BLE001 — a PE crash is a pod failure
            if not self.stop_event.is_set():
                self.crashed = True
                traceback.print_exc()
        finally:
            try:
                self._flush_all()  # drain buffered output before retiring
            except Exception:  # noqa: BLE001
                pass
            self.fabric.unpublish_pe(self.job, self.pe_id)
            if self.on_exit:
                self.on_exit(self)

    # ------------------------------------------------------------ streaming

    def _cr(self):
        return self.meta.get("consistentRegion")

    def _run_source(self) -> None:
        cfg = self.meta["operators"][0].get("config", {})
        if cfg.get("role") == "data":
            # Training data source: batches are pure functions of (seed,
            # offset) — "don't store (or send) what you can compute".  The op
            # exists as the dataflow's logical source; it only signals
            # liveness.
            while not self.stop_event.is_set():
                time.sleep(0.05)
            return
        limit = cfg.get("tuples", 0)  # 0 = unbounded
        interval = (self._cr() or {}).get("interval", 0)
        region = (self._cr() or {}).get("name", "region")
        offset = 0
        if self._cr():
            st = self.rest.get_cr_state(self.job, region)
            if st and st.get("lastCommitted", -1) >= 0:
                _, meta = self.rest.ckpt.load_shard(
                    self.job, region, st["lastCommitted"], f"pe{self.pe_id}")
                if meta:
                    offset = meta["offset"]
        while not self.stop_event.is_set():
            if limit and offset >= limit:
                break
            item = {"seq": offset, "data": offset % 97}
            self._emit(0, item, partition=offset)
            offset += 1
            self._maybe_flush()
            self._report_load()
            if interval and offset % interval == 0:
                # checkpoint barrier: everything the checkpoint covers must
                # be on the wire before the offset is declared durable
                self._flush_all()
                self.rest.ckpt.save_shard(self.job, region, offset,
                                          f"pe{self.pe_id}",
                                          meta={"offset": offset})
                self.rest.notify_checkpoint(self.job, region,
                                            self.pe_id, offset)
            if cfg.get("rate_sleep"):
                time.sleep(cfg["rate_sleep"])
        self._flush_all()
        # mark completion for finite sources
        self.rest.notify_source_done(self.job, self.pe_id)

    def _run_chain(self) -> None:
        """pipe/sink/router/server: batch pull, transform, batch push."""
        op = self.meta["operators"][0]
        is_sink = op["kind"] == "sink"
        work_sleep = op.get("config", {}).get("work_sleep", 0)
        seen = 0
        maxseq = -1
        while not self.stop_event.is_set():
            q = self.in_queues.get(0)
            if q is None:
                time.sleep(0.01)
                continue
            items = q.get_many(self.emit_batch, timeout=self._pull_timeout())
            self._report_load()
            if not items:
                self._maybe_flush()
                continue
            self.counts["in"] += len(items)
            for item in items:
                if work_sleep:  # synthetic per-tuple cost (load/bench knob)
                    time.sleep(work_sleep)
                if is_sink:
                    seen += 1
                    maxseq = max(maxseq, item.get("seq", -1))
                    if seen % 50 == 0 or item.get("flush"):
                        self.rest.report_sink(self.job, self.pe_id, seen, maxseq)
                else:
                    item = dict(item)
                    item["hops"] = item.get("hops", 0) + 1
                    self._emit(0, item, partition=item.get("seq"))
                    if work_sleep:
                        # slow per-tuple work: honour the linger bound
                        # inside the batch too, not only between batches
                        self._maybe_flush()
            self._maybe_flush()
        self._flush_all()
        if is_sink:
            self.rest.report_sink(self.job, self.pe_id, seen, maxseq)

    def _run_reducer(self) -> None:
        """Aggregates trainer metric tuples per step, forwards means."""
        width = self.meta.get("widths", {}).get("dp", 1)
        pending: dict = {}
        while not self.stop_event.is_set():
            q = self.in_queues.get(0)
            if q is None:
                time.sleep(0.01)
                continue
            items = q.get_many(self.emit_batch, timeout=self._pull_timeout())
            if not items:
                self._report_load()
                self._maybe_flush()
                continue
            self.counts["in"] += len(items)
            for item in items:
                step = item["step"]
                pending.setdefault(step, []).append(item["loss"])
                if len(pending[step]) == width:
                    mean = float(np.mean(pending.pop(step)))
                    self._emit(0, {"seq": step, "step": step, "loss": mean})
                    self.rest.report_metrics(
                        self.job, self.pe_id,
                        self.load_metrics({"step": step, "loss": mean}))
            self._maybe_flush()
        self._flush_all()

    # -------------------------------------------------------------- trainer

    def _run_trainer(self) -> None:
        from ..configs import reduced_config
        from ..models import ModelOptions, init_params, loss_fn
        from ..train.optim import OptimizerConfig, adamw_update, clip_by_global_norm, init_opt_state

        op = self.meta["operators"][0]
        cfg_app = op["config"]
        channel = op["channel"] if op["channel"] >= 0 else 0
        width = self.meta.get("widths", {}).get("dp", 1)
        arch_cfg = reduced_config(cfg_app["arch"]) if isinstance(
            cfg_app.get("arch"), str) else cfg_app["arch"]
        opts = ModelOptions(compute_dtype="float32")
        ocfg = OptimizerConfig(lr=cfg_app.get("lr", 1e-3), warmup_steps=10)
        batch_per_shard = cfg_app.get("batch_per_shard", 4)
        seq_len = cfg_app.get("seq_len", 64)
        max_steps = cfg_app.get("steps", 50)
        cr = self._cr()
        region = (cr or {}).get("name", "dp")
        interval = (cr or {}).get("interval", 10)

        source = StreamSource(vocab_size=arch_cfg.vocab_size,
                              batch=batch_per_shard, seq_len=seq_len,
                              seed=cfg_app.get("data_seed", 0), mode="lcg")

        params = init_params(jax.random.key(cfg_app.get("param_seed", 7)), arch_cfg)
        opt = init_opt_state(params)
        step = 0

        def lossf(p, b):
            return loss_fn(p, arch_cfg, b, opts, remat=False)

        grad_fn = jax.jit(jax.value_and_grad(lossf, has_aux=True))
        flat_params, treedef = jax.tree.flatten(params)

        def load_committed():
            nonlocal params, opt, step, flat_params
            st = self.rest.get_cr_state(self.job, region) if cr else None
            if st and st.get("lastCommitted", -1) >= 0:
                cstep = st["lastCommitted"]
                payload, meta = self.rest.ckpt.load_shard(
                    self.job, region, cstep, "params",
                    like={"params": params, "opt": opt})
                params = payload["params"]
                opt = payload["opt"]
                step = meta["step"]
                flat_params = jax.tree.leaves(params)

        load_committed()
        group = self.fabric.collective(self.job, region, width)
        epoch = group.epoch

        while not self.stop_event.is_set() and step < max_steps:
            step_t0 = time.monotonic()
            # deterministic shard: global batch at offset=step, this channel's
            # slice — recomputable from (seed, step, channel): no data state
            batch = source.batch_at(step * width + channel)
            (loss, _metrics), grads = grad_fn(params, batch)
            flat_g, gtree = jax.tree.flatten(grads)
            try:
                reduced = group.allreduce_mean(
                    ("step", step), [np.asarray(loss)] + [np.asarray(g) for g in flat_g],
                    epoch, rank=channel)
            except EpochAborted as e:
                epoch = e.epoch
                load_committed()
                continue
            mean_loss = float(reduced[0])
            grads = jax.tree.unflatten(gtree, reduced[1:])
            grads, _ = clip_by_global_norm(grads, ocfg.clip_norm)
            params, opt = adamw_update(ocfg, params, grads, opt,
                                       np.int32(step))
            step += 1
            self._emit(0, {"seq": step, "step": step, "loss": mean_loss})
            self._flush_all()  # one tuple per step: nothing to amortize
            if cr and step % interval == 0:
                if channel == 0:  # replicas identical post-allreduce
                    self.rest.ckpt.save_shard(self.job, region, step, "params",
                                              arrays={"params": params, "opt": opt},
                                              meta={"step": step})
                self.rest.notify_checkpoint(self.job, region, self.pe_id, step)
            self.rest.report_metrics(
                self.job, self.pe_id,
                self.load_metrics({"step": step, "loss": mean_loss,
                                   "stepTime": time.monotonic() - step_t0}))
        if step >= max_steps:
            self.rest.notify_source_done(self.job, self.pe_id)
