"""PE runtimes: the user code executing inside pods.

Each pod runs one PE (the paper's fundamental design decision, §5.1).  The
runtime implements the paper's PE translation layer: it publishes its input
ports to the fabric ("creates socket receivers + publishes port labels"),
resolves peer ports by *computed* names (no stored port labels — §5.2 name
resolution), reports connectivity/liveness/metrics through the REST facade
(§5.2 message bus), and participates in the consistent-region protocol
(§6.5).

Tuple transport hot path (the Fig. 8 bottleneck): emission is *buffered and
batched*.  ``_emit`` appends to a per-peer output buffer; a buffer is
flushed — one ``EndpointCache`` lookup + one ``put_many`` lock crossing for
the whole batch — when it reaches ``emit_batch`` tuples, when the oldest
buffered tuple is older than the ``emit_linger`` deadline, or on
checkpoint / drain / shutdown (so consistent-region and scale-down
semantics are unchanged: nothing a checkpoint covers is ever stranded in a
buffer).  Peer endpoints are resolved through the fabric's epoch-stamped
``EndpointCache`` — zero re-resolves while no binding moves — and pub/sub
routes (§6.4) are cached against the subscription broker's epoch instead
of being re-read from the REST facade per send.  The pull loops mirror
this: ``get_many`` moves a batch per lock crossing.

Emission batch size is *adaptive*: ``AdaptiveBatcher`` resizes
``emit_batch`` between per-operator ``emit_batch_min``/``emit_batch_max``
bounds from the PE's own load signals (input-ring fill, full pulls,
size-triggered flushes, blocked puts), and the linger deadline scales with
it — per-tuple emission with ~zero linger when idle, full batches with the
configured linger bound under backpressure.

Scale-down draining (the generation-change teardown gap): when the job
controller retires this PE on a width decrease, the kubelet forwards a
drain request and the runtime walks this state machine instead of dropping
its input rings::

    RUNNING --begin_drain--> DRAINING: keep pulling + processing
        DRAINING -> DRY    when every retiring upstream unpublished, all
                           input rings are empty, and they stayed empty
                           for the grace window  -> flush, exit clean
        DRAINING -> EXPIRED at drain_timeout -> flush what the deadline
                           allows, hand residual input tuples to the
                           surviving sibling channel (new generation,
                           computed by the job controller), count anything
                           undeliverable in ``tuplesDropped``, exit

Only after the runtime exits (and its final flush reached the fabric) does
the pod conductor delete the pod — §6.3's chain gains a drain phase.

Operator kinds:
- source / pipe / sink: the paper's streaming operators (tuple dataflow);
- trainer / reducer: a data-parallel JAX training shard + metric combine —
  gradient all-reduce goes over the fabric's CollectiveGroup ("ICI");
- router / server: replicated serving.

A PE with multiple fused operators executes them as an in-process chain
(operator fusion, §6.1 step 4).
"""

from __future__ import annotations

import random
import threading
import time
import traceback

import jax
import numpy as np

from ..data.stream import StreamSource
from .fabric import (EndpointCache, EpochAborted, Fabric, LatencyDigest,
                     ShutDown, Unreachable)


def affinity_route(key, width: int, table: dict, load: dict) -> int:
    """Prefix-affinity partition choice for the serve-job router.

    Requests sharing a prompt prefix (``key``) should land on the replica
    that already prefilled it — its KV blocks sit in that replica's prefix
    cache, so routing elsewhere forfeits the hit.  The first sighting of a
    prefix (or an owner invalidated by a width change) falls back to the
    least-loaded partition, which then becomes the prefix's owner.

    Pure function of its arguments: ``table`` (prefix -> owning partition)
    and ``load`` (partition -> requests routed) are caller-owned state,
    mutated in place.  Returns the partition index in ``[0, width)``.
    """
    owner = table.get(key)
    if owner is not None and owner < width:
        load[owner] = load.get(owner, 0) + 1
        return owner
    choice = min(range(width), key=lambda p: load.get(p, 0))
    table[key] = choice
    load[choice] = load.get(choice, 0) + 1
    return choice


class AdaptiveBatcher:
    """Metrics-driven ``emit_batch`` controller (replaces the static knob).

    Evaluated every ``interval`` seconds from the PE's *own* load signals —
    no control-plane round trip, per the resource-feedback-loop argument:
    the runtime already observes exactly the signals the metrics plane
    aggregates, one window earlier.

    Decision state machine::

        GROW   (batch *= 2, up to emit_batch_max) when the input ring is
               filling (fill >= grow_at), pulls keep coming back full, a
               flush blocked on a backpressured peer, or size-triggered
               flushes dominate the window (sustained emission rate);
        SHRINK (batch //= 2, down to emit_batch_min) when the ring is
               near-empty (fill <= shrink_at) and the window saw no full
               pull and no size flush — idle decays toward per-tuple
               emission for latency;
        HOLD   otherwise.

    ``linger`` scales the flush deadline with the current batch so a
    shrunken batch also stops waiting: ~zero linger at ``emit_batch_min``
    (latency mode), the configured bound at ``emit_batch_max``.
    """

    def __init__(self, cfg: dict, clock=time.monotonic):
        self.lo = max(1, int(cfg.get("emit_batch_min", 1)))
        self.hi = max(self.lo, int(cfg.get("emit_batch_max", 512)))
        self.enabled = bool(cfg.get("emit_adaptive", True))
        self.interval = float(cfg.get("emit_adapt_interval", 0.25))
        self.grow_at = float(cfg.get("emit_grow_at", 0.25))
        self.shrink_at = float(cfg.get("emit_shrink_at", 0.02))
        self.batch = min(self.hi, max(self.lo, int(cfg.get("emit_batch", 64))))
        self.clock = clock
        self._last = clock()
        self._pulls = 0
        self._full_pulls = 0
        self._size_flushes = 0
        self._blocked_flushes = 0
        self.adaptations = 0

    # ------------------------------------------------------ window signals

    def observe_pull(self, n: int) -> None:
        """One input pull returned ``n`` tuples (0 = empty/timeout)."""
        self._pulls += 1
        if n >= self.batch:
            self._full_pulls += 1

    def observe_flush(self, size_triggered: bool) -> None:
        if size_triggered:
            self._size_flushes += 1

    def observe_blocked_flush(self) -> None:
        self._blocked_flushes += 1

    # ---------------------------------------------------------- decisions

    def linger(self, bound: float) -> float:
        """Effective linger deadline for the current batch size."""
        if self.hi <= self.lo:
            return bound
        return bound * (self.batch - self.lo) / (self.hi - self.lo)

    def maybe_adapt(self, fill: float, now: float | None = None) -> bool:
        """Re-decide at most once per ``interval``; True iff batch changed."""
        if not self.enabled:
            return False
        now = self.clock() if now is None else now
        if now - self._last < self.interval:
            return False
        new = self.decide(self.batch, fill, self._pulls, self._full_pulls,
                          self._size_flushes, self._blocked_flushes,
                          self.lo, self.hi, self.grow_at, self.shrink_at)
        self._last = now
        self._pulls = self._full_pulls = 0
        self._size_flushes = self._blocked_flushes = 0
        if new != self.batch:
            self.batch = new
            self.adaptations += 1
            return True
        return False

    @staticmethod
    def decide(batch: int, fill: float, pulls: int, full_pulls: int,
               size_flushes: int, blocked_flushes: int, lo: int, hi: int,
               grow_at: float = 0.25, shrink_at: float = 0.02) -> int:
        """Pure decision: one window's signals -> next batch size."""
        pressured = (fill >= grow_at
                     or blocked_flushes > 0
                     or (pulls > 0 and full_pulls / pulls >= 0.5)
                     or size_flushes >= 4)
        if pressured:
            return min(batch * 2, hi)
        if fill <= shrink_at and full_pulls == 0 and size_flushes == 0:
            return max(batch // 2, lo)
        return batch


class PERuntime(threading.Thread):
    def __init__(self, *, job: str, pe_id: int, metadata: dict, fabric: Fabric,
                 rest, launch_count: int, stop_event: threading.Event,
                 on_exit=None, cpu_share=None, standby: bool = False,
                 pod_name: str | None = None):
        super().__init__(name=f"pe-{job}-{pe_id}"
                         + ("-standby" if standby else ""), daemon=True)
        self.job = job
        self.pe_id = pe_id
        self.meta = metadata
        self.fabric = fabric
        self.rest = rest
        self.launch_count = launch_count
        self.stop_event = stop_event
        self.on_exit = on_exit
        # warm-standby state (failover conductor, platform/failover.py): a
        # standby runtime HOLDS — no publishes, no identity writes under
        # (job, peId) — warming its state from the latest committed
        # checkpoint until promote() flips it into the primary identity.
        # ``pod_name`` overrides the computed primary pod name for exit
        # reporting while the runtime serves the standby pod record.
        self.standby = standby
        self.pod_name_override = pod_name
        self.promoted = False
        self.warmed_step = -1
        self._warm_state: dict = {}
        self._promote_event = threading.Event()
        self._entered_data_plane = False
        # node CPU share (the kubelet's oversubscription model): synthetic
        # per-tuple work stretches by the inverse share, so packing more
        # PEs than cores onto a node measurably slows each of them
        self.cpu_share = cpu_share or (lambda: 1.0)
        self.in_queues: dict = {}
        self.out_targets: dict = {}  # portId -> list[(peer pe, peer port)]
        self.crashed = False
        self.counts = {"in": 0, "out": 0, "routed": 0, "dropped": 0}
        self._last_load_report = 0.0
        # delivery-latency digest: consuming terminals (sinks) feed it from
        # the ingest watermark sources stamp into each tuple; percentiles
        # ride the load sample into the metrics plane
        self._lat = LatencyDigest()
        # batched emission state (flush policy: size + linger + barriers);
        # the batcher owns emit_batch between the per-operator min/max
        cfg0 = (self.meta.get("operators") or [{}])[0].get("config", {})
        self.batcher = AdaptiveBatcher(cfg0)
        self.emit_batch = self.batcher.batch
        self.emit_linger_max = float(cfg0.get("emit_linger", 0.002))
        self.emit_linger = (self.batcher.linger(self.emit_linger_max)
                            if self.batcher.enabled else self.emit_linger_max)
        self.endpoints = EndpointCache(fabric)
        # tuples pulled but not yet processed: still backlog *at this PE* —
        # without this, a large adaptive pull batch would make queue-fill
        # (the autoscaler's signal) read near-zero on a saturated channel
        self._pending_in = 0
        # drain state (scale-down): set by begin_drain from the kubelet
        self._drain: dict | None = None
        self._drain_deadline: float = 0.0
        self._drain_quiet_since: float | None = None
        self.drain_stats: dict | None = None
        self._out_buf: dict = {}  # (peer pe, peer port) -> list[tuple]
        # a flush that fails against a restarting peer re-buffers instead of
        # dropping; the cap bounds memory while the peer is away.  A peer
        # that is *partitioned* (alive behind a network fault, coming back)
        # earns a wider cap: shedding during a bounded window turns a
        # latency blip into permanent loss
        self._buffer_cap = max(8192, 4 * self.batcher.hi)
        self._partition_cap = 4 * self._buffer_cap
        # per-peer retry envelope for unreachable peers: capped exponential
        # backoff with deterministic jitter (seeded per PE, never wall
        # clock) so senders neither spin on the failing resolve path nor
        # stampede the peer the instant it heals
        self._peer_backoff: dict = {}  # peer -> (attempt, retry_at)
        self._backoff_rng = random.Random(0x9E3779B1 ^ (pe_id + 1))
        self.flush_retries = 0
        self._route_buf: list = []
        self._buf_since: float | None = None  # oldest unflushed append
        self._route_cache: list = []
        self._route_key = None  # (broker epoch, fabric epoch) of the cache
        self._routes_exist = False  # cheap per-tuple flag; see _refresh_routes

    # ------------------------------------------------------------- plumbing

    def _connect(self) -> None:
        for port in self.meta.get("inputs", []):
            # the fabric's transport backend mints the ring: in-process
            # deque by default, socket-looped when the platform runs the
            # cross-process data plane
            q = self.fabric.make_queue()
            self.in_queues[port["portId"]] = q
            self.fabric.publish(self.job, self.pe_id, port["portId"], q)
        for port in self.meta.get("outputs", []):
            # verify peers resolve (connection established), but keep the
            # *names* — sends go through the epoch-stamped EndpointCache so
            # a restarted peer's fresh endpoint is picked up on the next
            # epoch move (paper: PEs re-establish connections after
            # failures; names are computed, never stale)
            for peer_pe, peer_port in port["to"]:
                self.fabric.resolve(self.job, peer_pe, peer_port)
            self.out_targets[port["portId"]] = list(map(tuple, port["to"]))
        self._refresh_routes()  # notice routes matched before we started
        self.rest.notify_connected(self.job, self.pe_id)

    # ------------------------------------------------- batched emission

    def _refresh_routes(self) -> list:
        """Pub/sub route queues (Import/Export, §6.4), cached against the
        broker epoch (route set changes) and the fabric epoch (an importer
        PE restarted, so its resolved queue reference moved).  Called at
        flush/batch granularity — the per-tuple path only reads the
        ``_routes_exist`` flag this maintains."""
        key = (self.rest.routes_epoch(), self.fabric.epoch)
        if key != self._route_key:
            op0 = self.meta["operators"][0]
            self._route_cache = self.rest.get_routes(self.job, op0["name"])
            self._route_key = key
            self._routes_exist = bool(self._route_cache)
        return self._route_cache

    def _emit(self, port_id: int, item, partition: int | None = None) -> None:
        """Buffer ``item`` toward its target peer(s); out-tuple accounting
        happens per copy at flush time, on successful handoff to the peer
        queue (a broadcast to N peers counts N)."""
        targets = self.out_targets.get(port_id, ())
        if targets:
            if partition is not None:  # split into a parallel region
                self._buffer(targets[partition % len(targets)], item)
            else:
                for t in targets:
                    self._buffer(t, item)
        elif not self._routes_exist:
            # export-only emitter with no matched routes: no flush ever
            # runs, so probing per emit is the only way to notice the
            # first match (and this PE does no other transport work)
            self._refresh_routes()
        if self._routes_exist:
            self._route_buf.append(item)
            if self._buf_since is None:
                self._buf_since = time.monotonic()
            if len(self._route_buf) >= self.emit_batch:
                self.batcher.observe_flush(size_triggered=True)
                self._flush_routes()
                self._reset_linger_if_empty()

    def _buffer(self, peer: tuple, item) -> None:
        buf = self._out_buf.get(peer)
        if buf is None:
            buf = self._out_buf[peer] = []
        buf.append(item)
        if self._buf_since is None:
            self._buf_since = time.monotonic()
        if len(buf) >= self.emit_batch:
            self.batcher.observe_flush(size_triggered=True)
            self._flush_peer(peer, buf)
            # refresh here too: under sustained load size flushes pre-empt
            # the linger flush, and this must still notice new routes
            self._refresh_routes()
            self._reset_linger_if_empty()

    def _reset_linger_if_empty(self) -> None:
        """After a size-triggered flush the linger clock must not keep the
        drained batch's start time: the next lone tuple would inherit it and
        flush almost immediately, defeating the batching."""
        if not self._route_buf and all(not b for b in self._out_buf.values()):
            self._buf_since = None

    def _flush_peer(self, peer: tuple, buf: list) -> None:
        if not buf:
            return
        give_up = self.stop_event.is_set() or self._drain_expired()
        now = time.monotonic()
        backoff = self._peer_backoff.get(peer)
        if backoff is not None and now < backoff[1] and not give_up:
            # the peer is known-unreachable and inside its backoff window:
            # keep buffering (partition cap) instead of paying the failing
            # resolve path on every single emit batch
            excess = len(buf) - self._partition_cap
            if excess > 0:
                del buf[:excess]
                self.counts["dropped"] += excess
            return
        items = buf[:]
        del buf[:]
        # a stopping PE (voluntary restart) still gets a real chance to
        # land its tail on a live-but-full peer — only an expired drain is
        # in a hurry; an unbounded wait would stall pod teardown
        put_timeout = 0.2 if self._drain_expired() else \
            (1.0 if self.stop_event.is_set() else 2.0)
        try:
            q = self.endpoints.get(self.job, peer[0], peer[1], timeout=0.2)
            # timed from after resolution: a slow re-resolve (cache miss +
            # DNS delay) must not read as downstream backpressure
            t0 = time.monotonic()
            q.put_many(items, timeout=put_timeout)
            # counted on successful handoff so the metrics plane's
            # throughput rollup (what the autoscaler scales on) tracks
            # delivery, not buffering toward a possibly-dead peer
            self.counts["out"] += len(items)
            self._peer_backoff.pop(peer, None)
            if time.monotonic() - t0 > max(self.emit_linger_max, 0.002):
                # the put had to wait for room: downstream backpressure —
                # the batcher's grow signal for PEs with no input ring
                self.batcher.observe_blocked_flush()
        except ShutDown as e:
            # peer retired mid-put: the admitted prefix sits in a closed
            # ring — the fabric's residual carryover re-delivers it if the
            # peer restarts, but it is not counted as delivered here
            self._requeue(peer, buf, items[getattr(e, "admitted", 0):],
                          give_up)
        except Unreachable:
            # alive-but-partitioned peer: resolution failed before any put,
            # so nothing was admitted.  Re-buffer the whole batch under the
            # partition cap and arm the capped-exponential backoff — the
            # window is bounded and the peer is coming back, so shedding
            # here would turn a latency blip into loss
            self.flush_retries += 1
            attempt = backoff[0] + 1 if backoff is not None else 1
            delay = min(0.05 * (2 ** (attempt - 1)), 0.5)
            jitter = 0.5 + 0.5 * self._backoff_rng.random()
            self._peer_backoff[peer] = (attempt, now + delay * jitter)
            self._requeue(peer, buf, items, give_up, partitioned=True)
        except Exception as e:
            # peer down/restarting: a timed-out put to a live peer still
            # admitted a prefix that is genuinely in flight — count it;
            # the remainder re-buffers for the retry after the peer's
            # fresh endpoint publishes (epoch movement re-resolves it)
            admitted = getattr(e, "admitted", 0)
            self.counts["out"] += admitted
            self._requeue(peer, buf, items[admitted:], give_up)

    def _requeue(self, peer: tuple, buf: list, leftover: list,
                 give_up: bool, partitioned: bool = False) -> None:
        """Re-buffer undelivered tuples for a later flush (bounded), unless
        the runtime is stopping/expired — then they are accounted drops, not
        silently lost.  Outside a consistent region this turns the restart
        window of a surviving peer from tuple loss into added latency.  A
        partitioned peer gets the wider cap: its window is bounded and it
        is coming back, so the eager shed would be a self-inflicted drop."""
        if not leftover:
            return
        if give_up:
            self.counts["dropped"] += len(leftover)
            return
        buf[:0] = leftover
        cap = self._partition_cap if partitioned else self._buffer_cap
        excess = len(buf) - cap
        if excess > 0:  # peer gone too long: shed oldest, keep bounded
            del buf[:excess]
            self.counts["dropped"] += excess

    def _flush_routes(self) -> None:
        if not self._route_buf:
            return
        items = self._route_buf
        self._route_buf = []
        for q in self._refresh_routes():
            try:
                q.put_many(items, timeout=1.0)
                self.counts["routed"] += len(items)
            except ShutDown:
                pass  # importer retired: its queue is closed, not slow
            except Exception as e:
                self.counts["routed"] += getattr(e, "admitted", 0)

    def _flush_all(self, retry_until: float | None = None) -> None:
        self._refresh_routes()  # flush moments also notice new routes
        for peer, buf in self._out_buf.items():
            self._flush_peer(peer, buf)
        self._flush_routes()
        while retry_until is not None and \
                any(self._out_buf.values()) and \
                time.monotonic() < retry_until and \
                not self.stop_event.is_set():
            # draining: a peer mid-restart republishes within the window —
            # keep retrying until the deadline rather than dropping
            time.sleep(0.05)
            for peer, buf in self._out_buf.items():
                self._flush_peer(peer, buf)
        self._buf_since = None

    def _maybe_flush(self, now: float | None = None) -> None:
        """Linger deadline: flush everything once the oldest buffered tuple
        has waited ``emit_linger`` seconds."""
        if self._buf_since is None:
            return
        now = time.monotonic() if now is None else now
        if now - self._buf_since >= self.emit_linger:
            self.batcher.observe_flush(size_triggered=False)
            self._flush_all()

    def _pull_timeout(self, idle: float = 0.1) -> float:
        """Input-pull block time, capped by the linger deadline so buffered
        output is flushed on time even when no input arrives (and kept short
        while draining so the dry/grace check stays responsive)."""
        if self._drain is not None:
            idle = min(idle, max(self._drain["grace"] / 4, 0.01))
        if self._buf_since is None:
            return idle
        remaining = self._buf_since + self.emit_linger - time.monotonic()
        return min(idle, max(remaining, 0.0))

    # ----------------------------------------------- adaptive batch control

    def _adapt(self, now: float | None = None) -> None:
        """Re-evaluate the emit batch from the input-ring fill + the window
        signals the batcher collected; cheap (throttled inside)."""
        if not self.batcher.enabled:
            return
        depth, cap = self._pending_in, 0
        for q in self.in_queues.values():
            depth += len(q)
            cap += q.capacity
        if self.batcher.maybe_adapt(depth / cap if cap else 0.0, now):
            self.emit_batch = self.batcher.batch
            self.emit_linger = self.batcher.linger(self.emit_linger_max)

    # ------------------------------------------------------ drain (§6.3+)

    def begin_drain(self, req: dict) -> None:
        """Enter the Draining state (called from the kubelet thread when the
        job controller retires this PE on a width decrease).  ``req`` is the
        pod-status drain request: {timeout, grace, siblings, upstream}."""
        now = time.monotonic()
        self._drain_deadline = now + float(req.get("timeout", 5.0))
        self._drain_quiet_since = None
        # assignment last: the run loop keys off _drain being non-None
        self._drain = {
            "siblings": [tuple(s) for s in req.get("siblings", ())],
            "upstream": list(req.get("upstream", ())),
            "upstreamRestarting": [tuple(e) for e in
                                   req.get("upstreamRestarting", ())],
            "grace": float(req.get("grace", 0.3)),
            "started": now,
            # drops recorded mid-drain (e.g. a give-up _requeue in the
            # loop's trailing flush) must show in the drained report too
            "dropped0": self.counts["dropped"],
        }

    @property
    def draining(self) -> bool:
        return self._drain is not None

    def drain_upstream_gone(self, pe_id: int) -> None:
        """An upstream this drain was gated on is gone FOR GOOD (its pod
        stopped with no PE left to recreate it — a teardown, not a
        restart): nothing more can ever arrive from it, so waiting for its
        republish would only stall the drain into its timeout fallback."""
        d = self._drain
        if d is None:
            return
        d["upstreamRestarting"] = [(p, c) for p, c in d["upstreamRestarting"]
                                   if p != pe_id]
        d["upstream"] = [p for p in d["upstream"] if p != pe_id]

    def _drain_expired(self) -> bool:
        return self._drain is not None and \
            time.monotonic() >= self._drain_deadline

    def _drain_done(self) -> bool:
        """DRAINING -> DRY | EXPIRED.  Dry means: every retiring upstream
        unpublished (their final flush precedes unpublish, so nothing more
        can arrive from them), all input rings empty, and they stayed empty
        for the grace window (covering surviving upstreams mid-restart)."""
        d = self._drain
        if d is None:
            return False
        now = time.monotonic()
        if now >= self._drain_deadline:
            return True
        if any(len(q) for q in self.in_queues.values()):
            self._drain_quiet_since = None
            return False
        for up_pe in d["upstream"]:
            if self.fabric.pe_published(self.job, up_pe):
                self._drain_quiet_since = None
                return False
        for up_pe, baseline in d["upstreamRestarting"]:
            # a surviving upstream mid-restart: its NEW incarnation's
            # publish (strictly after the old one's final flush) is the
            # proof that nothing more from the old generation is coming
            if self.fabric.publish_count(self.job, up_pe) <= baseline:
                self._drain_quiet_since = None
                return False
        if self._drain_quiet_since is None:
            self._drain_quiet_since = now
            return False
        return now - self._drain_quiet_since >= d["grace"]

    def _finish_drain(self) -> None:
        """Exit path of a draining PE: flush (retrying while the deadline
        allows), hand residual input tuples to the surviving sibling, and
        account anything undeliverable as ``tuplesDropped``."""
        d = self._drain
        self._flush_all(retry_until=self._drain_deadline)
        dropped = handed = 0
        residual: list = []
        for q in self.in_queues.values():
            residual.extend(q.take_all())
        if residual:
            handed = self._handoff(residual, d["siblings"])
            dropped += len(residual) - handed
        for buf in self._out_buf.values():  # undeliverable after retries
            dropped += len(buf)
            del buf[:]
        dropped += len(self._route_buf)
        self._route_buf = []
        self.counts["dropped"] += dropped
        # report every drop since the drain began (a give-up _requeue in
        # the loop's trailing flush included): a clean report must mean
        # genuinely zero loss, not zero *residual* loss
        dropped = self.counts["dropped"] - d["dropped0"]
        self.drain_stats = {
            "tuplesDropped": dropped, "handedOff": handed,
            "residualInput": len(residual),
            "drainMs": (time.monotonic() - d["started"]) * 1000.0,
            "clean": dropped == 0,
        }
        self._report_load(force=True)  # final sample carries the drops

    def _handoff(self, items: list, siblings: list) -> int:
        """Reroute residual input tuples to a surviving sibling channel's
        input (the pr coordinator's new generation); returns how many were
        delivered — the rest fall back to the seed drop behaviour."""
        for pe_id, port_id in siblings:
            try:
                q = self.fabric.resolve(self.job, pe_id, port_id, timeout=1.0)
                q.put_many(items, timeout=2.0)
                return len(items)
            except ShutDown:
                continue
            except Exception as e:  # noqa: BLE001 — try the next sibling
                admitted = getattr(e, "admitted", 0)
                if admitted:
                    return admitted  # prefix landed; remainder timed out
        return 0

    # ------------------------------------------------------------- metrics

    def load_metrics(self, extra: dict | None = None) -> dict:
        """The per-PE load sample the metrics plane aggregates (§5.2 metrics
        reporting, extended with the queue-depth/backpressure signals the
        autoscale conductor scales on)."""
        op = self.meta["operators"][0]
        stats = [q.stats() for q in self.in_queues.values()]
        depth = sum(s["depth"] for s in stats) + self._pending_in
        cap = sum(s["capacity"] for s in stats)
        blocked = sum(s["blockedPuts"] for s in stats)
        batches = sum(s["getBatches"] for s in stats)
        dequeued = sum(s["dequeued"] for s in stats)
        cache = self.endpoints.stats()
        sample = {
            "operator": op["name"], "kind": op["kind"],
            "region": op.get("region"), "channel": op.get("channel", -1),
            "tuplesIn": self.counts["in"], "tuplesOut": self.counts["out"],
            "tuplesRouted": self.counts["routed"],
            "tuplesDropped": self.counts["dropped"],
            "emitBatch": self.emit_batch,
            "draining": self._drain is not None,
            "queueDepth": depth, "queueCapacity": cap,
            "backpressure": depth / cap if cap else 0.0,
            "blockedPuts": blocked,
            "queueHighWatermark": sum(s["highWatermark"] for s in stats),
            "avgPullBatch": dequeued / batches if batches else 0.0,
            "resolveHits": cache["hits"], "resolveMisses": cache["misses"],
            "resolveInvalidations": cache["invalidations"],
            "resolveRetries": cache["retries"],
            "flushRetries": self.flush_retries,
            "monotonic": time.monotonic(),
        }
        if self._lat.count:
            sample.update(self._lat.snapshot_ms())
        if extra:
            sample.update(extra)
        return sample

    def _report_load(self, extra: dict | None = None,
                     interval: float = 0.2, force: bool = False) -> None:
        now = time.monotonic()
        if not force and now - self._last_load_report < interval:
            return
        self._last_load_report = now
        sample = self.load_metrics(extra)
        if force:
            sample["final"] = True  # facades bypass their throttle on this
        self.rest.report_metrics(self.job, self.pe_id, sample)

    # ---------------------------------------------------------------- body

    def run(self) -> None:
        try:
            # Modeled container boot (image pull + process start).  A warm
            # standby pays this at creation, off the critical path; a cold
            # restart pays it before it can rejoin the data plane.
            boot = float(self.meta.get("startDelay", 0.0) or 0.0)
            if boot and self.stop_event.wait(boot):
                return
            if self.standby and not self._hold_standby():
                return  # stopped while holding: never touched the data plane
            self._entered_data_plane = True
            self._connect()
            kinds = [o["kind"] for o in self.meta["operators"]]
            if "trainer" in kinds:
                self._run_trainer()
            elif "source" in kinds:
                self._run_source()
            elif "reducer" in kinds:
                self._run_reducer()
            elif "server" in kinds:
                self._run_server()
            elif "router" in kinds:
                self._run_router()
            elif "sink" in kinds:
                self._run_chain()
            else:
                self._run_chain()
        except Exception:  # noqa: BLE001 — a PE crash is a pod failure
            if not self.stop_event.is_set():
                self.crashed = True
                traceback.print_exc()
        finally:
            if not self._entered_data_plane:
                # a standby that never promoted: it holds no publishes and
                # must NOT unpublish — (job, peId) endpoints belong to the
                # live primary
                if self.on_exit:
                    self.on_exit(self)
                return
            try:
                if self._drain is not None and not self.crashed and \
                        not self.stop_event.is_set():
                    # Draining exit: flush + handoff + drop accounting;
                    # only after this does unpublish close the rings
                    self._finish_drain()
                else:
                    # voluntary completion (finite source) gets a bounded
                    # window to land its tail on a slow peer; a stop or a
                    # crash flushes once and goes
                    voluntary = not self.crashed and \
                        not self.stop_event.is_set()
                    self._flush_all(retry_until=time.monotonic() + 5.0
                                    if voluntary else None)
                    leftover = sum(len(b) for b in self._out_buf.values())
                    leftover += len(self._route_buf)
                    if leftover:  # undelivered output is an accounted drop
                        self.counts["dropped"] += leftover
                        for b in self._out_buf.values():
                            del b[:]
                        self._route_buf = []
                        self._report_load(force=True)
            except Exception:  # noqa: BLE001
                pass
            self.fabric.unpublish_pe(self.job, self.pe_id)
            if self.on_exit:
                self.on_exit(self)

    # ------------------------------------------------------- warm standby

    def _hold_standby(self) -> bool:
        """The warm-standby hold loop: no publishes, no REST writes under
        the primary identity — only checkpoint re-warm passes at the
        policy's interval.  Returns True when promoted (proceed into
        ``_connect``: publish = single epoch bump, residual carryover
        preloads the dead primary's undelivered ring), False on stop."""
        interval = max(0.05, float(self.meta.get("standbyWarmInterval",
                                                 0.5) or 0.5))
        reported = None  # last warmed step told to the conductor
        self._warm_standby()
        while not self.stop_event.is_set():
            if reported != self.warmed_step:
                # readiness mark: boot is paid and a warm pass ran — only
                # now may the conductor flip StandbyReady (a promotion
                # before this would stall on the modeled boot)
                try:
                    self.rest.notify_standby_warm(self.job, self.pe_id,
                                                  self.warmed_step)
                except Exception:  # noqa: BLE001 — readiness is advisory
                    pass
                reported = self.warmed_step
            if self._promote_event.wait(timeout=interval):
                self.promoted = True
                return not self.stop_event.is_set()
            self._warm_standby()
        return False

    def _warm_standby(self) -> None:
        """One re-warm pass: page the latest committed checkpoint shards
        into memory so a promotion-time load is a cache hit, and record the
        warmed step for the conductor's readiness accounting."""
        cr = self._cr()
        ckpt = getattr(self.rest, "ckpt", None)
        if not cr or ckpt is None:
            return
        region = cr.get("name", "region")
        try:
            st = self.rest.get_cr_state(self.job, region)
            committed = st.get("lastCommitted", -1) if st else -1
            if committed < 0 or committed == self.warmed_step:
                return
            for shard in (f"pe{self.pe_id}", "params"):
                step, arrays, meta = ckpt.load_shard_at_or_before(
                    self.job, region, committed, shard)
                if step is not None:
                    self._warm_state[shard] = (step, arrays, meta)
            self.warmed_step = committed
        except Exception:  # noqa: BLE001 — warming is best-effort; the
            pass  # promotion-time load is the correctness path

    def promote(self, launch_count: int) -> None:
        """Flip this standby into the primary identity (failover conductor
        only).  The hold loop wakes immediately; exit reporting switches to
        the computed primary pod name."""
        self.launch_count = launch_count
        self.pod_name_override = None
        self._promote_event.set()

    # ------------------------------------------------------------ streaming

    def _cr(self):
        return self.meta.get("consistentRegion")

    def _run_source(self) -> None:
        cfg = self.meta["operators"][0].get("config", {})
        if cfg.get("role") == "data":
            # Training data source: batches are pure functions of (seed,
            # offset) — "don't store (or send) what you can compute".  The op
            # exists as the dataflow's logical source; it only signals
            # liveness.
            while not self.stop_event.is_set():
                time.sleep(0.05)
            return
        limit = cfg.get("tuples", 0)  # 0 = unbounded
        # optional payload ballast so transport benchmarks can sweep frame
        # sizes; rides the tuple like any other field (zero-copy on the
        # socket receive path)
        payload = bytes(int(cfg.get("payload_bytes", 0)))
        interval = (self._cr() or {}).get("interval", 0)
        region = (self._cr() or {}).get("name", "region")
        offset = 0
        if self._cr():
            st = self.rest.get_cr_state(self.job, region)
            if st and st.get("lastCommitted", -1) >= 0:
                # older-step fallback: a shard missing at the committed step
                # (writer missed a barrier) replays from the newest one
                _, _, meta = self.rest.ckpt.load_shard_at_or_before(
                    self.job, region, st["lastCommitted"], f"pe{self.pe_id}")
                if meta:
                    offset = meta["offset"]
        while not self.stop_event.is_set():
            if self._drain is not None:
                break  # a retiring source just stops emitting and flushes
            if limit and offset >= limit:
                break
            # "ts" is the ingest watermark: stamped once here, carried by
            # reference through every emit buffer / queue / handoff, and
            # turned into a delivery-latency observation at the sink
            item = {"seq": offset, "data": offset % 97,
                    "ts": time.monotonic()}
            if payload:
                item["payload"] = payload
            self._emit(0, item, partition=offset)
            offset += 1
            self._maybe_flush()
            self._adapt()
            self._report_load()
            if interval and offset % interval == 0:
                # checkpoint barrier: everything the checkpoint covers must
                # be on the wire before the offset is declared durable.
                # base_step = the last committed step, so unchanged shards
                # are hard-linked, not rewritten (incremental checkpoints)
                self._flush_all()
                st = self.rest.get_cr_state(self.job, region)
                base = st.get("lastCommitted", -1) if st else -1
                self.rest.ckpt.save_shard(self.job, region, offset,
                                          f"pe{self.pe_id}",
                                          meta={"offset": offset},
                                          base_step=base if base >= 0
                                          else None)
                self.rest.notify_checkpoint(self.job, region,
                                            self.pe_id, offset)
            if cfg.get("rate_sleep"):
                time.sleep(cfg["rate_sleep"])
        self._flush_all()
        # mark completion for finite sources
        self.rest.notify_source_done(self.job, self.pe_id)

    def _run_chain(self) -> None:
        """pipe/sink/router/server: batch pull, transform, batch push."""
        op = self.meta["operators"][0]
        is_sink = op["kind"] == "sink"
        work_sleep = op.get("config", {}).get("work_sleep", 0)
        report_every = max(1, int(op.get("config", {}).get("report_every", 50)))
        seen = 0
        maxseq = -1
        while not self.stop_event.is_set():
            if self._drain_done():
                break  # Draining -> dry (or expired): exit via _finish_drain
            q = self.in_queues.get(0)
            if q is None:
                time.sleep(0.01)
                continue
            items = q.get_many(self.emit_batch, timeout=self._pull_timeout())
            self.batcher.observe_pull(len(items))
            self._adapt()
            self._report_load()
            if not items:
                self._maybe_flush()
                continue
            self.counts["in"] += len(items)
            self._pending_in = len(items)
            # synthetic work stretches by the node's inverse CPU share (1.0
            # unless the kubelet's oversubscription model is on)
            eff_sleep = work_sleep / max(self.cpu_share(), 0.05) \
                if work_sleep else 0
            for item in items:
                if eff_sleep:  # synthetic per-tuple cost (load/bench knob)
                    time.sleep(eff_sleep)
                if is_sink:
                    seen += 1
                    maxseq = max(maxseq, item.get("seq", -1))
                    ts = item.get("ts")
                    if ts is not None:
                        self._lat.observe(time.monotonic() - ts)
                    if seen % report_every == 0 or item.get("flush"):
                        self.rest.report_sink(self.job, self.pe_id, seen, maxseq)
                else:
                    item = dict(item)
                    item["hops"] = item.get("hops", 0) + 1
                    self._emit(0, item, partition=item.get("seq"))
                    if eff_sleep:
                        # slow per-tuple work: honour the linger bound and
                        # keep heartbeats fresh inside the batch too, not
                        # only between batches
                        self._maybe_flush()
                        self._report_load()
                self._pending_in -= 1
            self._maybe_flush()
        self._flush_all()
        if is_sink:
            self.rest.report_sink(self.job, self.pe_id, seen, maxseq)

    # ------------------------------------------------------------- serving

    def _run_router(self) -> None:
        """Serve-job request router: partitions requests across the server
        replicas.  With an input port (pub/sub import feeding it) it is the
        plain pull-partition-push chain; without one it synthesizes the
        request stream itself from its config (``requests`` total at one
        request per ``request_sleep`` seconds) — the serve job's load
        driver for benchmarks and autoscale tests.

        With ``prefix_groups`` configured, every request carries a prompt-
        prefix id (``i % prefix_groups``) and is routed with *prefix
        affinity* (``affinity_route``): repeats of a prefix go to the
        replica whose paged engine already caches it; fresh prefixes take
        the least-loaded replica.  Otherwise the seed's round-robin-by-seq
        partitioning is unchanged."""
        cfg = self.meta["operators"][0].get("config", {})
        if self.meta.get("inputs"):
            return self._run_chain()
        limit = int(cfg.get("requests", 0))  # 0 = unbounded
        sleep = float(cfg.get("request_sleep", 0.001))
        tokens = int(cfg.get("tokens_per_request", 8))
        prompt_tokens = int(cfg.get("prompt_tokens", 0))
        groups = int(cfg.get("prefix_groups", 0))
        affinity: dict = {}  # prefix id -> owning partition
        routed: dict = {}  # partition -> requests routed (load proxy)
        i = 0
        while not self.stop_event.is_set():
            if self._drain is not None:
                break
            if limit and i >= limit:
                break
            item = {"seq": i, "rid": i, "tokens": tokens,
                    "ts": time.monotonic()}
            if prompt_tokens:
                item["promptTokens"] = prompt_tokens
            part = i
            if groups:
                item["prefix"] = i % groups
                width = max(1, len(self.out_targets.get(0, ())))
                part = affinity_route(item["prefix"], width, affinity, routed)
            self._emit(0, item, partition=part)
            i += 1
            self._maybe_flush()
            self._adapt()
            self._report_load()
            if sleep:
                time.sleep(sleep)
        self._flush_all()
        self.rest.notify_source_done(self.job, self.pe_id)

    def _run_server(self) -> None:
        """Serving replica: continuous batching over ``slots`` request
        slots, reporting ServeEngine-shaped slot-occupancy samples into the
        metrics plane (``occupancy`` / ``meanOccupancy`` / ``slotsBusy`` /
        ``numSlots`` — the same keys ``ServeEngine.metrics()`` exports), so
        the target-tracking autoscale policy can drive the ``replicas``
        region width from occupancy.

        Each admitted request occupies a slot for ``tokens`` engine ticks
        (one token per tick — the continuous-batching cost model;
        ``token_sleep`` is the per-tick decode cost, stretched by the
        node's inverse CPU share like any synthetic work).  Finished
        requests emit a response tuple downstream.

        With ``kv_blocks`` configured the replica runs the *paged* cost
        model instead of bare slots: admission charges the request's block
        footprint against the pool (mirroring ``PagedServeEngine``'s
        banker's admission), prompts prefill in ``prefill_chunk``-token
        ticks, and prompt prefixes it has prefilled before are served from
        a modeled prefix cache (no prefill, one divergence block).  The
        paged signals — ``blocksFree`` / ``blocksCached`` /
        ``prefixHitRate`` / ``prefillBacklog`` — ride the same load
        samples, so the metrics plane and the PID autoscaler can consume
        them exactly like occupancy."""
        op = self.meta["operators"][0]
        cfg = op.get("config", {})
        slots = max(1, int(cfg.get("slots", 4)))
        token_sleep = float(cfg.get("token_sleep", 0.001))
        default_tokens = int(cfg.get("tokens_per_request", 8))
        kv_blocks = int(cfg.get("kv_blocks", 0))  # 0 = seed slot model
        block_size = max(1, int(cfg.get("block_size", 16)))
        prefill_chunk = max(1, int(cfg.get("prefill_chunk", 8)))

        def bft(n: int) -> int:  # blocks for tokens (ceil)
            return -(-n // block_size) if n > 0 else 0

        seen_prefixes: set = set()
        cached_blocks = 0
        held_blocks = 0
        admissions = 0
        prefix_hits = 0
        pending: list = []  # pulled but blocked on pool space
        # entry: [item, decode tokens left, prefill tokens left, blocks held]
        active: list = []
        ticks = 0
        busy_ticks = 0

        def admit(item) -> bool:
            nonlocal held_blocks, cached_blocks, admissions, prefix_hits
            tokens = int(item.get("tokens", default_tokens))
            prompt = int(item.get("promptTokens", 0))
            if not kv_blocks:
                active.append([item, tokens, 0, 0])
                return True
            pfx = item.get("prefix")
            hit = pfx is not None and pfx in seen_prefixes
            # a cache hit skips the prompt's blocks and prefill entirely,
            # paying one divergence (copy-on-write) block instead
            need = bft((0 if hit else prompt) + tokens) + (1 if hit else 0)
            free_now = kv_blocks - held_blocks - cached_blocks
            if need > free_now:
                evict = min(need - free_now, cached_blocks)
                cached_blocks -= evict  # LRU eviction, modeled in bulk
                free_now += evict
            if need > free_now:
                return False  # memory-aware admission: hold in pending
            held_blocks += need
            admissions += 1
            prefix_hits += 1 if hit else 0
            active.append([item, tokens, 0 if hit else prompt, need])
            return True

        def finish(entry) -> None:
            nonlocal held_blocks, cached_blocks
            held_blocks -= entry[3]
            item = dict(entry[0])
            item["hops"] = item.get("hops", 0) + 1
            self._emit(0, item, partition=item.get("seq"))

        def tick_entries(entries) -> list:
            nonlocal cached_blocks
            done = []
            for entry in entries:
                if entry[2] > 0:  # chunked prefill phase
                    entry[2] -= min(prefill_chunk, entry[2])
                    if entry[2] == 0 and kv_blocks:
                        pfx = entry[0].get("prefix")
                        if pfx is not None and pfx not in seen_prefixes:
                            # commit the prefilled prompt to the cache
                            seen_prefixes.add(pfx)
                            cached_blocks += bft(
                                int(entry[0].get("promptTokens", 0)))
                    continue
                entry[1] -= 1
                if entry[1] <= 0:
                    done.append(entry)
            return done

        while not self.stop_event.is_set():
            if self._drain_done():
                break
            q = self.in_queues.get(0)
            if q is None:
                time.sleep(0.01)
                continue
            free = slots - len(active) - len(pending)
            if free > 0:
                items = q.get_many(free, timeout=self._pull_timeout(
                    idle=0.02 if active else 0.1))
                if items:
                    self.counts["in"] += len(items)
                    pending.extend(items)
            while pending and len(active) < slots and admit(pending[0]):
                pending.pop(0)
            if active:
                ticks += 1
                busy_ticks += len(active)
                if token_sleep:
                    time.sleep(token_sleep / max(self.cpu_share(), 0.05))
                for entry in tick_entries(active):
                    active.remove(entry)
                    finish(entry)
            occupancy = len(active) / slots
            sample = {
                "occupancy": occupancy, "slotsBusy": len(active),
                "numSlots": slots,
                "meanOccupancy": busy_ticks / (ticks * slots) if ticks else 0.0,
            }
            if kv_blocks:
                sample.update({
                    "blocksTotal": kv_blocks,
                    "blocksFree": kv_blocks - held_blocks - cached_blocks,
                    "blocksCached": cached_blocks,
                    "prefixHitRate": (prefix_hits / admissions
                                      if admissions else 0.0),
                    "prefillBacklog": sum(e[2] for e in active) + sum(
                        int(it.get("promptTokens", 0)) for it in pending),
                })
            self._report_load(sample)
            self._maybe_flush()
            self._adapt()
        # finish the admitted requests before exiting (the slot-level
        # analogue of _run_chain completing its in-hand batch): a stop or
        # drain costs at most tokens x token_sleep extra, never a request
        while (active or pending) and not self.crashed:
            while pending and len(active) < slots and admit(pending[0]):
                pending.pop(0)
            if not active:
                break  # pool wedged with nothing running: drop pendings
            for entry in tick_entries(active):
                active.remove(entry)
                finish(entry)
            if token_sleep:
                time.sleep(token_sleep)
        self._flush_all()

    def _run_reducer(self) -> None:
        """Aggregates trainer metric tuples per step, forwards means."""
        width = self.meta.get("widths", {}).get("dp", 1)
        pending: dict = {}
        while not self.stop_event.is_set():
            if self._drain_done():
                break
            q = self.in_queues.get(0)
            if q is None:
                time.sleep(0.01)
                continue
            items = q.get_many(self.emit_batch, timeout=self._pull_timeout())
            self.batcher.observe_pull(len(items))
            self._adapt()
            if not items:
                self._report_load()
                self._maybe_flush()
                continue
            self.counts["in"] += len(items)
            self._pending_in = len(items)
            for item in items:
                step = item["step"]
                pending.setdefault(step, []).append(item["loss"])
                if len(pending[step]) == width:
                    mean = float(np.mean(pending.pop(step)))
                    self._emit(0, {"seq": step, "step": step, "loss": mean})
                    self.rest.report_metrics(
                        self.job, self.pe_id,
                        self.load_metrics({"step": step, "loss": mean}))
                self._pending_in -= 1
            self._maybe_flush()
        self._flush_all()

    # -------------------------------------------------------------- trainer

    def _run_trainer(self) -> None:
        from ..configs import reduced_config
        from ..models import ModelOptions, init_params, loss_fn
        from ..train.optim import OptimizerConfig, adamw_update, clip_by_global_norm, init_opt_state

        op = self.meta["operators"][0]
        cfg_app = op["config"]
        channel = op["channel"] if op["channel"] >= 0 else 0
        width = self.meta.get("widths", {}).get("dp", 1)
        arch_cfg = reduced_config(cfg_app["arch"]) if isinstance(
            cfg_app.get("arch"), str) else cfg_app["arch"]
        opts = ModelOptions(compute_dtype="float32")
        ocfg = OptimizerConfig(lr=cfg_app.get("lr", 1e-3), warmup_steps=10)
        batch_per_shard = cfg_app.get("batch_per_shard", 4)
        seq_len = cfg_app.get("seq_len", 64)
        max_steps = cfg_app.get("steps", 50)
        cr = self._cr()
        region = (cr or {}).get("name", "dp")
        interval = (cr or {}).get("interval", 10)

        source = StreamSource(vocab_size=arch_cfg.vocab_size,
                              batch=batch_per_shard, seq_len=seq_len,
                              seed=cfg_app.get("data_seed", 0), mode="lcg")

        params = init_params(jax.random.key(cfg_app.get("param_seed", 7)), arch_cfg)
        opt = init_opt_state(params)
        step = 0

        def lossf(p, b):
            return loss_fn(p, arch_cfg, b, opts, remat=False)

        grad_fn = jax.jit(jax.value_and_grad(lossf, has_aux=True))
        flat_params, treedef = jax.tree.flatten(params)

        def load_committed():
            nonlocal params, opt, step, flat_params
            st = self.rest.get_cr_state(self.job, region) if cr else None
            if st and st.get("lastCommitted", -1) >= 0:
                cstep = st["lastCommitted"]
                payload, meta = self.rest.ckpt.load_shard(
                    self.job, region, cstep, "params",
                    like={"params": params, "opt": opt})
                params = payload["params"]
                opt = payload["opt"]
                step = meta["step"]
                flat_params = jax.tree.leaves(params)

        load_committed()
        group = self.fabric.collective(self.job, region, width)
        epoch = group.epoch

        while not self.stop_event.is_set() and step < max_steps:
            if self._drain is not None:
                # a retiring trainer stops at a step boundary; the region's
                # consistent-region replay covers anything uncommitted
                break
            step_t0 = time.monotonic()
            # deterministic shard: global batch at offset=step, this channel's
            # slice — recomputable from (seed, step, channel): no data state
            batch = source.batch_at(step * width + channel)
            (loss, _metrics), grads = grad_fn(params, batch)
            flat_g, gtree = jax.tree.flatten(grads)
            try:
                reduced = group.allreduce_mean(
                    ("step", step), [np.asarray(loss)] + [np.asarray(g) for g in flat_g],
                    epoch, rank=channel)
            except EpochAborted as e:
                epoch = e.epoch
                load_committed()
                continue
            mean_loss = float(reduced[0])
            grads = jax.tree.unflatten(gtree, reduced[1:])
            grads, _ = clip_by_global_norm(grads, ocfg.clip_norm)
            params, opt = adamw_update(ocfg, params, grads, opt,
                                       np.int32(step))
            step += 1
            self._emit(0, {"seq": step, "step": step, "loss": mean_loss})
            self._flush_all()  # one tuple per step: nothing to amortize
            if cr and step % interval == 0:
                if channel == 0:  # replicas identical post-allreduce
                    st = self.rest.get_cr_state(self.job, region)
                    base = st.get("lastCommitted", -1) if st else -1
                    self.rest.ckpt.save_shard(self.job, region, step, "params",
                                              arrays={"params": params, "opt": opt},
                                              meta={"step": step},
                                              base_step=base if base >= 0
                                              else None)
                self.rest.notify_checkpoint(self.job, region, self.pe_id, step)
            self.rest.report_metrics(
                self.job, self.pe_id,
                self.load_metrics({"step": step, "loss": mean_loss,
                                   "stepTime": time.monotonic() - step_t0}))
        if step >= max_steps:
            self.rest.notify_source_done(self.job, self.pe_id)
