"""Metrics plane: per-PE load samples -> per-operator/region rollups.

PEs already push raw metric samples through ``RestFacade.report_metrics``
(they land in pod status).  The ``MetricsPlane`` conductor observes those
pod events, keeps rolling windows per (job, PE), and publishes per-operator
and per-ParallelRegion aggregates into a job's ``Metrics`` resource — so
every downstream consumer (the autoscale conductor, dashboards, tests) gets
metrics through the normal resource/event system instead of a side channel.

Aggregates per region:
- ``backpressure``: mean input-queue fill across the region's channels —
  the primary elasticity signal;
- ``throughput``:   sum of per-channel tuple rates (d tuplesIn / dt over the
  window; tuplesOut for sources);
- ``queueDepth``:   summed depths; ``stepTime``: mean trainer step time;
- ``emitBatch``:    mean adaptive output batch the channels run at;
- ``occupancy``:    mean slot occupancy across serving replicas (the
  ServeEngine-shaped samples server PEs report) — the signal the
  target-tracking autoscale policy drives toward its setpoint;
- ``tuplesDropped``: cumulative drain-fallback drops, *including* PEs whose
  pods are already retired — a retiring PE's final (forced) sample is folded
  into a per-job ledger when its pod deletes, so scale-down losses stay
  visible in the Metrics CRD after the evidence pod is gone.

Like every conductor, its state is recomputable: windows rebuild from the
live stream after a restart, and the published resource is just a cache of
them.  The Metrics resource is created by this conductor (the way the pod
conductor creates pods) but only mutated through the metrics coordinator.
"""

from __future__ import annotations

import time
from collections import deque

from ..core import Conductor, Event, EventType
from . import crds
from .api import ApiClient, ensure_api


class MetricsPlane(Conductor):
    """Aggregates pod metric samples and publishes Metrics resources."""

    kinds = (crds.POD, crds.JOB)

    def __init__(self, store, namespace, coords, trace=None, *, api=None,
                 window: float = 5.0, publish_interval: float = 0.2,
                 clock=time.monotonic):
        super().__init__(store, "metrics-plane", trace)
        self.namespace = namespace
        self.coords = coords
        self.api = ensure_api(api, store, namespace, coords, trace)
        self.window = window
        self.publish_interval = publish_interval
        self.clock = clock
        self._samples: dict = {}  # (job, peId) -> deque[(t, sample)]
        self._retired_drops: dict = {}  # job -> {region|None: dropped}
        self._last_publish: dict = {}  # job -> t

    # ------------------------------------------------------------ ingestion

    def on_event(self, event: Event) -> None:
        if event.resource.kind == crds.JOB:
            if event.type == EventType.DELETED:
                # the job is gone: drop *all* per-job state, not just the
                # per-pod windows — the retired-drop ledger and the publish
                # throttle otherwise leak one entry per job for the life of
                # the harness
                job = event.resource.name
                self._retired_drops.pop(job, None)
                self._last_publish.pop(job, None)
                for k in [k for k in self._samples if k[0] == job]:
                    del self._samples[k]
            return
        pod = event.resource
        job = pod.spec.get("job")
        pe_id = pod.spec.get("peId")
        if job is None or pe_id is None:
            return
        if event.type == EventType.DELETED:
            win = self._samples.pop((job, pe_id), None)
            if win:
                # fold a retired PE's terminal drop count into the ledger
                # so scale-down losses outlive the pod that reported them
                _, last = win[-1]
                dropped = last.get("tuplesDropped", 0)
                if dropped:
                    per_region = self._retired_drops.setdefault(job, {})
                    region = last.get("region")
                    per_region[region] = per_region.get(region, 0) + dropped
            return
        sample = pod.status.get("metrics")
        if not isinstance(sample, dict) or "operator" not in sample:
            return  # not a load sample (e.g. bare sink/heartbeat status)
        self.ingest(job, pe_id, sample)
        self.publish(job)

    def ingest(self, job: str, pe_id: int, sample: dict,
               now: float | None = None) -> None:
        now = self.clock() if now is None else now
        win = self._samples.setdefault((job, pe_id), deque())
        # unrelated pod status patches re-deliver the last sample; appending
        # the duplicate at a later t would dilute the computed rates
        if not win or win[-1][1] != sample:
            win.append((now, sample))
        cutoff = now - self.window
        while win and win[0][0] < cutoff:
            win.popleft()

    # ---------------------------------------------------------- aggregation

    @staticmethod
    def _rate(win) -> float:
        """Tuple rate over the window from cumulative counters."""
        if len(win) < 2:
            return 0.0
        (t0, s0), (t1, s1) = win[0], win[-1]
        if t1 <= t0:
            return 0.0
        key = "tuplesIn" if s1.get("kind") != "source" else "tuplesOut"
        d = s1.get(key, 0) - s0.get(key, 0)
        return max(d, 0) / (t1 - t0)

    _LAT_KEYS = ("latencyP50", "latencyP95", "latencyP99")

    @classmethod
    def _latency_fold(cls, acc: dict, sample: dict) -> None:
        """Fold one PE's latency digest into a rollup accumulator
        (sample-weighted mean per percentile — an approximation, but the
        digests are already estimates and sinks dominate their own jobs)."""
        n = sample.get("latencySamples", 0)
        if not n:
            return
        acc["latencySamples"] = acc.get("latencySamples", 0) + n
        acc["latencyMax"] = max(acc.get("latencyMax", 0.0),
                                sample.get("latencyMax", 0.0))
        for k in cls._LAT_KEYS:
            acc[k] = acc.get(k, 0.0) + n * sample.get(k, 0.0)

    @classmethod
    def _latency_finish(cls, acc: dict) -> dict:
        n = acc.get("latencySamples", 0)
        if not n:
            return {}
        out = {k: round(acc[k] / n, 3) for k in cls._LAT_KEYS}
        out["latencyMax"] = round(acc["latencyMax"], 3)
        out["latencySamples"] = n
        return out

    @staticmethod
    def _region_zero(dropped: int = 0) -> dict:
        """Empty region aggregate (also the shape published for regions
        whose every channel already retired but whose drops remain)."""
        return {"channels": 0, "backpressure": 0.0, "throughput": 0.0,
                "queueDepth": 0, "blockedPuts": 0, "stepTime": 0.0,
                "emitBatch": 0.0, "occupancy": 0.0, "tuplesDropped": dropped,
                "blocksFree": 0, "blocksCached": 0, "prefillBacklog": 0,
                "prefixHitRate": 0.0}

    def aggregate(self, job: str) -> dict:
        """Pure rollup of the current windows for one job."""
        operators: dict = {}
        regions: dict = {}
        region_lat: dict = {}
        job_lat: dict = {}
        retired = self._retired_drops.get(job, {})
        dropped_total = sum(retired.values())
        for (j, pe_id), win in self._samples.items():
            if j != job or not win:
                continue
            _, latest = win[-1]
            rate = self._rate(win)
            op_entry = {**latest, "rate": rate, "peId": pe_id}
            operators[latest["operator"]] = op_entry
            dropped_total += latest.get("tuplesDropped", 0)
            self._latency_fold(job_lat, latest)
            region = latest.get("region")
            if not region:
                continue
            self._latency_fold(region_lat.setdefault(region, {}), latest)
            agg = regions.setdefault(region, {
                **self._region_zero(retired.get(region, 0)),
                "stepTimeSamples": 0, "occupancySamples": 0,
                "prefixSamples": 0})
            agg["channels"] += 1
            agg["backpressure"] += latest.get("backpressure", 0.0)
            agg["throughput"] += rate
            agg["queueDepth"] += latest.get("queueDepth", 0)
            agg["blockedPuts"] += latest.get("blockedPuts", 0)
            agg["emitBatch"] += latest.get("emitBatch", 0)
            agg["tuplesDropped"] += latest.get("tuplesDropped", 0)
            if "occupancy" in latest:
                # serving replicas (ServeEngine-shaped slot samples): mean
                # slot occupancy is the target-tracking policy's signal
                agg["occupancy"] += latest["occupancy"]
                agg["occupancySamples"] += 1
            # paged-serving signals (PagedServeEngine-shaped samples):
            # pool inventory sums across replicas, hit rate is a mean
            agg["blocksFree"] += latest.get("blocksFree", 0)
            agg["blocksCached"] += latest.get("blocksCached", 0)
            agg["prefillBacklog"] += latest.get("prefillBacklog", 0)
            if "prefixHitRate" in latest:
                agg["prefixHitRate"] += latest["prefixHitRate"]
                agg["prefixSamples"] += 1
            if latest.get("stepTime"):
                agg["stepTime"] += latest["stepTime"]
                agg["stepTimeSamples"] += 1
        for region, agg in regions.items():
            agg["backpressure"] /= max(agg["channels"], 1)
            agg["emitBatch"] /= max(agg["channels"], 1)
            if agg["occupancySamples"]:
                agg["occupancy"] /= agg["occupancySamples"]
            if agg["stepTimeSamples"]:
                agg["stepTime"] /= agg["stepTimeSamples"]
            if agg["prefixSamples"]:
                agg["prefixHitRate"] /= agg["prefixSamples"]
            del agg["stepTimeSamples"], agg["occupancySamples"]
            del agg["prefixSamples"]
        # regions whose every channel already retired still report drops
        for region, n in retired.items():
            if region and region not in regions:
                regions[region] = self._region_zero(n)
        # delivery-latency percentiles (ms), from the sink digests: per
        # region where a member reported them, and per job at the top level
        for region, acc in region_lat.items():
            if region in regions:
                regions[region].update(self._latency_finish(acc))
        return {"operators": operators, "regions": regions,
                "tuplesDropped": dropped_total,
                **self._latency_finish(job_lat)}

    # ------------------------------------------------------------ publishing

    def publish(self, job: str, force: bool = False) -> bool:
        """Write the rollup into the job's Metrics resource (throttled)."""
        now = self.clock()
        if not force and now - self._last_publish.get(job, -1e9) < self.publish_interval:
            return False
        job_res = self.store.try_get(crds.JOB, job, self.namespace)
        if job_res is None or job_res.terminating:
            return False  # job torn down: don't resurrect labeled resources
        self._last_publish[job] = now
        rollup = self.aggregate(job)
        name = crds.metrics_name(job)
        if not self.store.exists(crds.METRICS, name, self.namespace):
            try:
                self.api.metrics.create(crds.make_metrics(job, self.namespace))
            except Exception:  # lost a create race (or teardown began and
                # the owner is terminating); the update below lands if the
                # resource exists, no-ops otherwise
                pass
            if not self.store.exists(crds.JOB, job, self.namespace):
                # teardown swept the job between our existence check and the
                # create: remove the orphan or wait_terminated never drains
                self.store.try_delete(crds.METRICS, name, self.namespace)
                return False
        self.api.metrics.patch_status(
            name, {**rollup, "updatedAt": now}, requester=self.name)
        self._record("publish", (crds.METRICS, self.namespace, name),
                     f"regions={len(rollup['regions'])}")
        return True
