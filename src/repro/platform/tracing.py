"""Span-based causal-chain tracing (the observability plane's substrate).

``CausalTrace`` (core/patterns.py) records flat ``(actor, action, key,
detail)`` tuples — enough to assert that a chain *happened*, but chains
cannot be timed, linked, or exported.  ``SpanTracer`` grows it into span
tracing:

- every span has an id, a parent link, and wall-clock start/end, so a causal
  chain (event -> controller -> conductor -> coordinator command -> kubelet
  -> runtime) renders as a *parented span tree with durations*;
- context propagates two ways: synchronously via a thread-local span stack
  (controller -> conductor -> coordinator all run on one delivery thread),
  and across actors/threads via a token registry — the actor that arms an
  operation ``attach``-es its span under a token (e.g. ``drain:<pod>``) and
  the downstream actor reacting to the resulting event looks it up with
  ``context``;
- spans live in a bounded ring and export as Chrome trace-event JSON
  (``chrome://tracing`` / Perfetto) or a human-readable indented tree.

``SpanTracer`` subclasses ``CausalTrace``, so the platform's existing
``trace`` plumbing *is* the tracer: every actor already holds a reference,
and all flat-trace assertions (``chain()``/``actors_for()``/``entries``)
keep working.  Finished spans are mirrored into the flat trace as
``span:<name>`` records so ``chain()`` shows timings inline.

Instrumented hot paths (each a §8 pathology made measurable):

==========================  =====================================
token                       causal chain covered
==========================  =====================================
``drain:<pod>``             job-controller arm -> kubelet begin-drain
                            -> runtime drain -> pod-conductor retire
``pod:<pod>``               pod failure/restart -> pod recreate ->
                            scheduler bind -> kubelet start -> connected
``migrate:<pe>``            pressure verdict -> pod delete -> recovery
                            chain above -> migration complete
``fault:<name>``            chaos injection -> fault executed -> the
                            platform's recovery chain -> healed
==========================  =====================================
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Iterator, Optional

from ..core import CausalTrace


class Span:
    """One timed link of a causal chain."""

    __slots__ = ("span_id", "trace_id", "parent_id", "actor", "name", "key",
                 "t0", "t1", "attrs")

    def __init__(self, span_id: int, trace_id: int, parent_id: Optional[int],
                 actor: str, name: str, key: Optional[tuple], t0: float,
                 attrs: dict):
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.actor = actor
        self.name = name
        self.key = key
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration_ms(self) -> Optional[float]:
        return None if self.t1 is None else (self.t1 - self.t0) * 1000.0

    def keystr(self) -> str:
        return f"{self.key[0]}/{self.key[2]}" if self.key else "-"

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        dur = self.duration_ms
        tail = "open" if dur is None else f"{dur:.1f}ms"
        return f"<span {self.span_id} {self.actor}:{self.name}:{self.keystr()} {tail}>"


class SpanTracer(CausalTrace):
    """A ``CausalTrace`` that also records parented, timed spans.

    Drop-in for every ``trace=`` parameter in the platform; actors that only
    know ``CausalTrace`` keep recording flat entries, instrumented actors
    call the span API.  All methods are thread-safe.
    """

    def __init__(self, maxlen: int | None = 100_000,
                 span_maxlen: int | None = 20_000,
                 clock=time.monotonic) -> None:
        super().__init__(maxlen=maxlen)
        self.clock = clock
        self._span_lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=span_maxlen)
        self._next_id = 1
        self._ctx: dict[str, Span] = {}
        self._tls = threading.local()

    # ------------------------------------------------------------- lifecycle

    def start_span(self, actor: str, name: str, key: Optional[tuple] = None,
                   parent: "Span | int | None" = None, **attrs) -> Span:
        """Open a span.  ``parent`` may be a Span, a span id, or None — in
        which case the innermost span open on *this thread* (if any) becomes
        the parent, so synchronous nesting links up automatically."""
        if parent is None:
            stack = getattr(self._tls, "stack", None)
            if stack:
                parent = stack[-1]
        parent_id = parent.span_id if isinstance(parent, Span) else parent
        trace_id = parent.trace_id if isinstance(parent, Span) else None
        with self._span_lock:
            sid = self._next_id
            self._next_id += 1
            if trace_id is None:
                trace_id = self._trace_id_of(parent_id) if parent_id else sid
            span = Span(sid, trace_id, parent_id, actor, name, key,
                        self.clock(), dict(attrs))
            self._spans.append(span)
        return span

    def _trace_id_of(self, span_id: int) -> int:
        # caller holds _span_lock
        for s in reversed(self._spans):
            if s.span_id == span_id:
                return s.trace_id
        return span_id  # parent evicted from the ring: start a new tree

    def end_span(self, span: Optional[Span], **attrs) -> None:
        if span is None or span.t1 is not None:
            return
        span.t1 = self.clock()
        if attrs:
            span.attrs.update(attrs)
        if span.key is not None:
            # mirror the finished span into the flat trace so chain() shows
            # the timing inline with the observe/modify records around it
            self.record(span.actor, f"span:{span.name}", span.key,
                        f"{span.duration_ms:.1f}ms")

    @contextmanager
    def span(self, actor: str, name: str, key: Optional[tuple] = None,
             parent: "Span | int | None" = None, **attrs) -> Iterator[Span]:
        """Scoped span; pushed on the thread-local stack so nested
        ``start_span``/``span`` calls on the same thread auto-parent."""
        sp = self.start_span(actor, name, key, parent, **attrs)
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            self.end_span(sp)

    # ------------------------------------------- cross-actor context passing

    def attach(self, token: str, span: Span) -> Span:
        """Publish ``span`` as the causal context for ``token`` so a later
        actor (on any thread) can parent to it via ``context(token)``."""
        with self._span_lock:
            self._ctx[token] = span
        return span

    def context(self, token: str) -> Optional[Span]:
        with self._span_lock:
            return self._ctx.get(token)

    def detach(self, token: str) -> Optional[Span]:
        with self._span_lock:
            return self._ctx.pop(token, None)

    # ---------------------------------------------------------------- query

    def spans(self, name: Optional[str] = None, actor: Optional[str] = None,
              trace_id: Optional[int] = None) -> list[Span]:
        with self._span_lock:
            snap = list(self._spans)
        if name is not None:
            snap = [s for s in snap if s.name == name]
        if actor is not None:
            snap = [s for s in snap if s.actor == actor]
        if trace_id is not None:
            snap = [s for s in snap if s.trace_id == trace_id]
        return snap

    def clear(self) -> None:
        super().clear()
        with self._span_lock:
            self._spans.clear()
            self._ctx.clear()

    # --------------------------------------------------------------- export

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (load in chrome://tracing or Perfetto).

        Spans become ``X`` complete events (one row per actor); parent links
        become ``s``/``f`` flow events so cross-actor chains draw as arrows.
        """
        snap = self.spans()
        tids: dict[str, int] = {}
        events: list[dict] = []
        for s in snap:
            tid = tids.setdefault(s.actor, len(tids) + 1)
            t1 = s.t1 if s.t1 is not None else self.clock()
            ev = {
                "name": s.name, "cat": s.key[0] if s.key else "span",
                "ph": "X", "pid": 1, "tid": tid,
                "ts": s.t0 * 1e6, "dur": max(t1 - s.t0, 0.0) * 1e6,
                "args": {"key": s.keystr(), "span_id": s.span_id,
                         "trace_id": s.trace_id, **s.attrs},
            }
            events.append(ev)
            if s.parent_id is not None:
                flow = {"cat": "causal", "name": "chain", "pid": 1,
                        "id": s.span_id}
                parent = next((p for p in snap if p.span_id == s.parent_id), None)
                if parent is not None:
                    ptid = tids.setdefault(parent.actor, len(tids) + 1)
                    events.append({**flow, "ph": "s", "tid": ptid,
                                   "ts": parent.t0 * 1e6})
                    events.append({**flow, "ph": "f", "bp": "e", "tid": tid,
                                   "ts": s.t0 * 1e6})
        for actor, tid in tids.items():
            events.append({"ph": "M", "pid": 1, "tid": tid,
                           "name": "thread_name", "args": {"name": actor}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh, indent=1)
        return path

    # -------------------------------------------------------------- renderer

    def render(self, root: "Span | int | None" = None) -> str:
        """Human-readable indented span tree.

        With ``root`` (a Span or span id), render that subtree; without,
        render every root span's tree in start order.
        """
        snap = self.spans()
        children: dict[Optional[int], list[Span]] = {}
        for s in snap:
            children.setdefault(s.parent_id, []).append(s)
        by_id = {s.span_id: s for s in snap}

        def fmt(s: Span) -> str:
            dur = s.duration_ms
            tail = "(open)" if dur is None else f"{dur:.1f}ms"
            extra = " ".join(f"{k}={v}" for k, v in sorted(s.attrs.items()))
            return f"{s.name} {s.keystr()} [{s.actor}] {tail}" + (f" {extra}" if extra else "")

        lines: list[str] = []

        def walk(s: Span, depth: int) -> None:
            lines.append("  " * depth + fmt(s))
            for c in sorted(children.get(s.span_id, []), key=lambda c: c.t0):
                walk(c, depth + 1)

        if root is not None:
            rid = root.span_id if isinstance(root, Span) else root
            if rid in by_id:
                walk(by_id[rid], 0)
        else:
            roots = [s for s in snap
                     if s.parent_id is None or s.parent_id not in by_id]
            for s in sorted(roots, key=lambda s: s.t0):
                walk(s, 0)
        return "\n".join(lines)


def span_tracer(trace) -> Optional[SpanTracer]:
    """The span view of a trace, or None when the platform was handed a
    plain ``CausalTrace`` (instrumentation then degrades to flat records)."""
    return trace if isinstance(trace, SpanTracer) else None


# Context-registry token helpers: one vocabulary for every instrumented path,
# so the arming actor and the reacting actor agree without importing each
# other.

def drain_token(pod_name: str) -> str:
    return f"drain:{pod_name}"


def pod_token(pod_name: str) -> str:
    return f"pod:{pod_name}"


def migrate_token(pe_name: str) -> str:
    return f"migrate:{pe_name}"


def fault_token(fault_name: str) -> str:
    return f"fault:{fault_name}"


__all__ = ["Span", "SpanTracer", "span_tracer", "drain_token", "pod_token",
           "migrate_token", "fault_token"]
