"""Data-plane fabric: queues ("TCP"), name resolution ("DNS"), collectives
("ICI") and checkpoint/abort epochs.

The platform (controllers/conductors) never touches tuple or tensor data —
exactly the paper's control/data-plane separation (§8 discussion).  PEs find
each other through ``resolve`` (with a configurable propagation delay that
reproduces the paper's DNS-latency observations), stream tuples over bounded
queues, and data-parallel trainer shards combine gradients through
``CollectiveGroup`` — the stand-in for ICI all-reduce, which on real
hardware belongs to XLA, not the platform.

Hot-path design (the Fig. 8 bottleneck): the control path (publish /
resolve) is slow and rare; the data path must never pay for it per tuple.

- ``Fabric`` keeps an *endpoint epoch* bumped on every ``publish`` /
  ``unpublish_pe``.  Senders hold an ``EndpointCache`` whose entries stay
  valid while the epoch is unchanged — the paper's §5.2 computed-names
  contract (names never go stale, only bindings move, and every binding
  move bumps the epoch) is what makes cache-and-invalidate safe.
- ``resolve`` waits on a ``Condition`` signalled by ``publish`` instead of
  sleep-polling the registry.
- *How* tuples cross an endpoint is the ``Transport``'s business
  (``transport.py``): the in-process deque ring is the default backend,
  the socket backend frames batches over local TCP with identical put
  semantics, and the cross-process host (``prochost.py``) registers
  remote-address handles here in place of local rings.  The fabric itself
  only names endpoints and classifies their state — and for the
  retired-vs-partitioned call it asks the transport whether a handle is
  still *deliverable*, never just whether a thread-local queue object
  exists.

``CollectiveGroup`` supports *epoch aborts*: when the consistent-region
operator initiates rollback-and-recovery, in-flight barriers abort with
``EpochAborted`` so surviving shards rewind to the committed checkpoint
instead of deadlocking on a dead peer.

Scale-down draining (the §6.3 teardown gap): two fabric mechanisms keep
in-flight tuples alive across generation changes outside consistent
regions —

- **drain-only endpoints**: ``set_draining`` marks a retiring PE's
  endpoints.  Fresh resolution (``resolve`` with the default
  ``include_draining=False`` — new-generation producers, pub/sub route
  matching) no longer finds them, while *established* senders re-resolving
  through their ``EndpointCache`` still do, so a retiring PE can receive
  the tail of its upstreams' buffers while it pulls its input dry.  The
  mark bumps the epoch, so every sender cache invalidates at the moment
  the drain begins.
- **residual carryover**: ``unpublish_pe`` stashes whatever tuples were
  still sitting in the retired queues (or, across a process boundary, the
  residuals the remote host collected and shipped back); the next
  ``publish`` of the same computed name (a *restarting* PE of the
  surviving generation) preloads them into the fresh ring, in order, ahead
  of new traffic.  A PE restart for a metadata change therefore loses
  nothing that had already been delivered to it.  Residuals for names that
  never republish (truly retired PEs — the drain phase empties those rings
  first) expire after ``residual_ttl`` seconds.

Drain endpoint state machine::

    published --set_draining--> draining --unpublish_pe--> closed
        ^                                                    |
        +------------- publish (same name, restart; ---------+
                        residuals preloaded)
"""

from __future__ import annotations

import random
import threading
import time

import numpy as np

# The ring and the exception vocabulary moved to transport.py with the
# backend split; re-exported here so every existing import keeps working.
from .transport import (EpochAborted, ShutDown, Transport,  # noqa: F401
                        TupleQueue, Unreachable, default_transport)


class P2Quantile:
    """Streaming quantile estimator (Jain & Chlamtac's P² algorithm).

    O(1) memory (five markers), pure python, no sorting — the data plane can
    afford to feed it per delivered tuple.  Used by sink PEs to estimate
    delivery-latency percentiles from the ingest watermarks sources stamp
    into tuples; the estimates ride the normal load-sample path into the
    metrics plane.
    """

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError("quantile must be in (0, 1)")
        self.p = p
        self.n = 0
        self._q: list[float] = []       # marker heights
        self._pos: list[float] = []     # marker positions (1-based)
        self._want: list[float] = []    # desired positions
        self._dpos = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        if self.n <= 5:
            self._q.append(x)
            self._q.sort()
            if self.n == 5:
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0 + 4.0 * d for d in self._dpos]
            return
        q, pos = self._q, self._pos
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = next(i for i in range(4) if q[i] <= x < q[i + 1])
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._dpos[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
               (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                d = 1.0 if d > 0 else -1.0
                qp = self._parabolic(i, d)
                if not q[i - 1] < qp < q[i + 1]:
                    qp = self._linear(i, d)
                q[i] = qp
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self._q, self._pos
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        q, n = self._q, self._pos
        j = i + (1 if d > 0 else -1)
        return q[i] + d * (q[j] - q[i]) / (n[j] - n[i])

    def value(self) -> float:
        if self.n == 0:
            return 0.0
        if self.n <= 5:
            srt = sorted(self._q)
            idx = min(int(round(self.p * (len(srt) - 1))), len(srt) - 1)
            return srt[idx]
        return self._q[2]


class LatencyDigest:
    """P50/P95/P99 delivery-latency digest a sink feeds per tuple.

    Latencies are observed in seconds (now - ingest watermark) and reported
    in milliseconds, matching the SLO CRD's ``latencyP95Ms`` vocabulary.
    """

    QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))

    def __init__(self):
        self._est = {label: P2Quantile(q) for label, q in self.QUANTILES}
        self.count = 0
        self.max = 0.0

    def observe(self, latency_s: float) -> None:
        self.count += 1
        if latency_s > self.max:
            self.max = latency_s
        for est in self._est.values():
            est.add(latency_s)

    def snapshot_ms(self) -> dict:
        """``{latencyP50: .., latencyP95: .., latencyP99: .., latencyMax: ..,
        latencySamples: n}`` in milliseconds (empty dict before any sample)."""
        if not self.count:
            return {}
        out = {f"latency{label.upper()[0]}{label[1:]}": round(est.value() * 1e3, 3)
               for label, est in self._est.items()}
        out["latencyMax"] = round(self.max * 1e3, 3)
        out["latencySamples"] = self.count
        return out


class CollectiveGroup:
    """Barrier-average over ``width`` contributors with abortable epochs."""

    def __init__(self, width: int):
        self.width = width
        self.epoch = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._contrib: dict = {}  # key -> rank-ordered list of (rank, value)
        self._result: dict = {}

    def allreduce_mean(self, key, value, epoch: int, timeout: float = 30.0,
                       rank: int = 0):
        """Blocks until all ``width`` shards contribute (same epoch).

        Contributions are summed in ``rank`` order — sorted once, by the
        completing shard — so the float reduction is deterministic
        regardless of thread arrival order, which is what makes recovered
        training bit-identical to an uninterrupted run."""
        with self._cond:
            if epoch != self.epoch:
                raise EpochAborted(self.epoch)
            bucket = self._contrib.setdefault((epoch, key), [])
            bucket.append((rank, value))
            if len(bucket) == self.width:
                arrs = [v for _, v in sorted(bucket, key=lambda rv: rv[0])]
                self._result[(epoch, key)] = [
                    sum(np.asarray(a[i], dtype=np.float32) for a in arrs) / self.width
                    for i in range(len(arrs[0]))
                ]
                self._cond.notify_all()
            deadline = time.monotonic() + timeout
            while (epoch, key) not in self._result:
                if epoch != self.epoch:
                    raise EpochAborted(self.epoch)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"collective {key} timed out")
                self._cond.wait(timeout=min(remaining, 0.1))
            res = self._result[(epoch, key)]
            bucket = self._contrib.get((epoch, key))
            if bucket is not None:
                bucket.pop()
                if not bucket:
                    # last leaver cleans up
                    self._contrib.pop((epoch, key), None)
                    self._result.pop((epoch, key), None)
            return res

    def abort(self) -> int:
        with self._cond:
            self.epoch += 1
            self._contrib.clear()
            self._result.clear()
            self._cond.notify_all()
            return self.epoch


class Fabric:
    """Cluster-wide connection registry + DNS + collectives.

    ``epoch`` is the endpoint generation: it moves only when a binding
    moves (publish/unpublish).  Senders cache resolved endpoints against it
    through ``EndpointCache`` and never touch the registry lock on the
    tuple hot path while the epoch stands still.
    """

    def __init__(self, dns_delay: float = 0.0, residual_ttl: float = 30.0,
                 transport: Transport | None = None):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._endpoints: dict = {}  # (job, pe_id, port_id) -> endpoint handle
        self._published_at: dict = {}
        self._draining: set = set()  # (job, pe_id, port_id) drain-only keys
        self._partitioned: dict = {}  # (job, pe_id) -> heal deadline (monotonic)
        self._residuals: dict = {}  # key -> (stashed_at, [tuples])
        self._publish_counts: dict = {}  # (job, pe_id) -> cumulative publishes
        self._collectives: dict = {}  # (job, region) -> CollectiveGroup
        self.transport = transport if transport is not None \
            else default_transport()
        self.dns_delay = dns_delay
        self.residual_ttl = residual_ttl
        self.epoch = 0

    def make_queue(self, maxsize: int = 1024):
        """Mint an input ring on this fabric's transport backend — the one
        call sites use so the backend choice stays a fabric construction
        detail, never a per-PE decision."""
        return self.transport.make_queue(maxsize)

    def publish(self, job: str, pe_id: int, port_id: int, q) -> None:
        key = (job, pe_id, port_id)
        with self._cond:
            self._sweep_residuals()
            residual = self._residuals.pop(key, None)
            if residual is not None:
                # a restarted PE reclaims its predecessor's undelivered
                # input: carryover rides ahead of new traffic, in order
                q.preload(residual[1])
            self._endpoints[key] = q
            self._published_at[key] = time.monotonic()
            self._draining.discard(key)
            self._publish_counts[(job, pe_id)] = \
                self._publish_counts.get((job, pe_id), 0) + 1
            self.epoch += 1
            self._cond.notify_all()

    def unpublish_pe(self, job: str, pe_id: int,
                     residuals: dict | None = None) -> None:
        """Retire every endpoint of a PE, stashing undelivered input for the
        residual-carryover republish.  ``residuals`` (``{port_id: [tuples]}``)
        overrides the local ``take_all`` when the ring lives in another
        process — the remote host drains it there and ships the leftovers
        back over the control channel."""
        with self._cond:
            removed = [key for key in self._endpoints if key[:2] == (job, pe_id)]
            now = time.monotonic()
            for key in removed:
                q = self._endpoints.pop(key)
                leftovers = residuals.get(key[2], []) if residuals is not None \
                    else q.take_all()
                q.close()
                if leftovers:
                    self._residuals[key] = (now, leftovers)
                self._published_at.pop(key, None)
                self._draining.discard(key)
            self._sweep_residuals(now)
            if removed:
                self.epoch += 1
                self._cond.notify_all()

    def set_draining(self, job: str, pe_id: int) -> int:
        """Mark a retiring PE's endpoints drain-only and bump the epoch.

        Fresh resolution no longer finds them (no *new* producers attach);
        established senders — whose ``EndpointCache`` just invalidated on
        the epoch move — re-resolve with ``include_draining=True`` and can
        still deliver their buffered tail while the PE pulls its ring dry."""
        marked = 0
        with self._cond:
            for key in self._endpoints:
                if key[:2] == (job, pe_id):
                    self._draining.add(key)
                    marked += 1
            if marked:
                self.epoch += 1
                self._cond.notify_all()
        return marked

    def pe_published(self, job: str, pe_id: int) -> bool:
        """True while any endpoint of the PE is still bound (a draining PE
        waits for its retiring *upstreams* to unpublish before declaring
        its input dry — their final flush happens before they unpublish)."""
        with self._lock:
            return any(key[:2] == (job, pe_id) for key in self._endpoints)

    def publish_count(self, job: str, pe_id: int) -> int:
        """Cumulative publishes by a PE — the restart detector.  A draining
        PE whose surviving upstream is restarting into the new generation
        waits for this to move past the value captured at drain time: the
        fresh incarnation publishes only after the old one exited, and the
        old one flushes its buffered tail before exiting."""
        with self._lock:
            return self._publish_counts.get((job, pe_id), 0)

    def _sweep_residuals(self, now: float | None = None) -> None:
        """Caller holds the lock.  Residuals whose name never republished
        (retired for good, or the job tore down) expire after the TTL."""
        now = time.monotonic() if now is None else now
        for key in [k for k, (t, _) in self._residuals.items()
                    if now - t > self.residual_ttl]:
            del self._residuals[key]

    # ------------------------------------------------- partitions (chaos)

    def partition(self, job: str, pe_id: int, duration: float) -> None:
        """Make a PE's endpoints unreachable for ``duration`` seconds.

        Models a network partition of an *alive* peer: the queues stay
        bound (the PE keeps draining its own ring), but ``resolve`` treats
        them as absent and raises ``Unreachable`` on timeout.  The epoch
        bump drops every sender cache, so established senders fall off
        their cached references onto the failing resolve path immediately —
        their flushes fail for the window and they must re-buffer.  Heals
        by deadline (lazily, or eagerly via ``heal``)."""
        with self._cond:
            self._partitioned[(job, pe_id)] = time.monotonic() + duration
            self.epoch += 1
            self._cond.notify_all()

    def heal(self, job: str, pe_id: int) -> bool:
        """End a partition early; True if one was in force."""
        with self._cond:
            was = self._partitioned.pop((job, pe_id), None) is not None
            if was:
                self.epoch += 1
                self._cond.notify_all()
            return was

    def _partition_deadline(self, job: str, pe_id: int) -> float | None:
        """Caller holds the lock.  The heal deadline if a partition is in
        force, expiring (and bumping the epoch) lazily when passed."""
        deadline = self._partitioned.get((job, pe_id))
        if deadline is None:
            return None
        if time.monotonic() >= deadline:
            del self._partitioned[(job, pe_id)]
            self.epoch += 1
            self._cond.notify_all()
            return None
        return deadline

    def partitioned(self, job: str, pe_id: int) -> bool:
        with self._cond:
            return self._partition_deadline(job, pe_id) is not None

    def invalidate(self) -> None:
        """Bump the endpoint epoch without moving a binding — used when
        transport-level liveness changes out from under the registry (a
        worker process died), so sender caches drop and the next resolve
        re-classifies against the now-dead handles."""
        with self._cond:
            self.epoch += 1
            self._cond.notify_all()

    def wait_epoch(self, last: int, timeout: float = 0.5) -> int:
        """Block until the endpoint epoch moves past ``last`` (or until the
        timeout); returns the current epoch.  The cross-process bridge uses
        this to push epoch movement to worker processes without polling."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self.epoch == last:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
            return self.epoch

    def _live_keys(self, job: str, pe_id: int) -> tuple:
        """Caller holds the lock: (all endpoint keys of the PE, the subset
        the transport still considers deliverable)."""
        keys = [k for k in self._endpoints if k[:2] == (job, pe_id)]
        live = [k for k in keys
                if self.transport.endpoint_alive(self._endpoints[k])]
        return keys, live

    def endpoint_state(self, job: str, pe_id: int) -> str:
        """Classify a peer: ``partitioned`` | ``draining`` | ``published`` |
        ``retired`` (was bound once, gone now) | ``unknown`` (never seen).

        The retired-vs-unreachable distinction is what lets a sender decide
        between re-buffering (the peer will come back) and counting its
        tail as dropped (the peer is gone for good).  Liveness is the
        *transport's* call and it outranks a partition window: bound
        handles whose backing process died classify retired even while a
        partition is in force — retrying cannot resurrect a dead process,
        only a restart (which republishes) can."""
        with self._cond:
            keys, live = self._live_keys(job, pe_id)
            if keys and not live:
                return "retired"
            if self._partition_deadline(job, pe_id) is not None:
                return "partitioned"
            if keys:
                return "draining" if all(k in self._draining for k in keys) \
                    else "published"
            if self._publish_counts.get((job, pe_id), 0) > 0:
                return "retired"
            return "unknown"

    def resolve(self, job: str, pe_id: int, port_id: int,
                timeout: float = 30.0, include_draining: bool = False):
        """Name resolution with propagation delay (paper §8: DNS latency).

        Event-driven: waits on the registry condition (signalled by
        ``publish``) rather than polling, waking early only to honour the
        configured DNS propagation delay.  Endpoints marked drain-only are
        invisible unless ``include_draining`` — fresh producers and pub/sub
        route matching must not attach to a retiring PE, but established
        senders (``EndpointCache``) may still deliver their buffered tail.

        On timeout the failure is typed by transport liveness: a partition
        over endpoints that can still deliver raises ``Unreachable`` (the
        peer is coming back — retry), while a partition whose endpoints are
        all dead degrades to plain ``TimeoutError`` (retired semantics —
        the window cannot outlive the process)."""
        key = (job, pe_id, port_id)
        deadline = time.monotonic() + timeout
        with self._cond:
            while True:
                partition_ends = self._partition_deadline(job, pe_id)
                q = None if partition_ends is not None \
                    else self._endpoints.get(key)
                if q is not None and not include_draining and \
                        key in self._draining:
                    q = None  # drain-only: invisible to fresh resolution
                now = time.monotonic()
                if q is not None:
                    ready_at = self._published_at.get(key, 0.0) + self.dns_delay
                    if now >= ready_at:
                        return q
                    wait = min(deadline, ready_at) - now
                else:
                    # a partitioned peer wakes us at its heal deadline even
                    # if nobody publishes in between
                    wait = (min(deadline, partition_ends)
                            if partition_ends is not None else deadline) - now
                if wait <= 0:
                    if partition_ends is not None:
                        keys, live = self._live_keys(job, pe_id)
                        if live or not keys:
                            raise Unreachable(
                                f"resolve({job}, pe {pe_id}, port {port_id}): "
                                f"partitioned")
                    raise TimeoutError(f"resolve({job}, pe {pe_id}, port {port_id})")
                self._cond.wait(wait)

    def collective(self, job: str, region: str, width: int) -> CollectiveGroup:
        with self._lock:
            key = (job, region)
            grp = self._collectives.get(key)
            if grp is None or grp.width != width:
                grp = CollectiveGroup(width)
                self._collectives[key] = grp
            return grp

    def abort_collectives(self, job: str) -> None:
        with self._lock:
            groups = [g for (j, _), g in self._collectives.items() if j == job]
        for g in groups:
            g.abort()


class EndpointCache:
    """Sender-side resolution cache, invalidated by fabric-epoch movement.

    The zero-re-resolve contract: while ``fabric.epoch`` is unchanged no
    binding has moved, so a hit costs one dict lookup and no lock.  When
    the epoch moves (a peer published or retired anywhere in the cluster)
    the whole cache drops and the next send re-resolves — which is exactly
    how a restarted peer's fresh endpoint is picked up without the sender
    ever holding a stale reference past one epoch.

    The miss path carries a retry envelope (capped exponential backoff with
    deterministic jitter): a failed resolve of a *partitioned or recently
    bound* peer is retried ``max_retries`` times before the failure
    surfaces, because the peer is expected back; a peer the fabric
    classifies ``retired`` fails fast — no amount of retrying resurrects a
    drained PE, and the sender's tail is a legitimate counted drop.  The
    classification consults transport liveness, so a peer whose *process*
    died inside a partition window fails fast too instead of burning the
    whole envelope on a handle nothing can revive.
    """

    def __init__(self, fabric: Fabric, *, max_retries: int = 2,
                 backoff_base: float = 0.05, backoff_cap: float = 0.5,
                 rng: random.Random | None = None):
        self.fabric = fabric
        self._epoch = fabric.epoch
        self._queues: dict = {}
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        # jitter decorrelates senders without breaking deterministic replay:
        # the stream is seeded, never wall-clock
        self._rng = rng if rng is not None else random.Random(0x5EED)
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.retries = 0

    def _backoff(self, attempt: int) -> float:
        step = min(self.backoff_base * (2 ** attempt), self.backoff_cap)
        return step * (0.5 + 0.5 * self._rng.random())

    def get(self, job: str, pe_id: int, port_id: int,
            timeout: float = 0.2):
        epoch = self.fabric.epoch
        if epoch != self._epoch:
            if self._queues:
                self.invalidations += 1
                self._queues.clear()
            self._epoch = epoch
        key = (job, pe_id, port_id)
        q = self._queues.get(key)
        if q is not None:
            self.hits += 1
            return q
        self.misses += 1
        # an established sender may still reach a drain-only endpoint: the
        # retiring PE is pulling its ring dry and wants our buffered tail
        attempt = 0
        while True:
            try:
                q = self.fabric.resolve(job, pe_id, port_id, timeout=timeout,
                                        include_draining=True)
                break
            except Unreachable:
                # a dead process inside a partition window is retired, not
                # partitioned — the envelope must not retry the unrevivable
                if attempt >= self.max_retries or \
                        self.fabric.endpoint_state(job, pe_id) == "retired":
                    raise
                self.retries += 1
                attempt += 1
                time.sleep(self._backoff(attempt - 1))
            except TimeoutError:
                # retired peers fail fast; anything else may just be slow to
                # (re)publish — retry inside the envelope
                if attempt >= self.max_retries or \
                        self.fabric.endpoint_state(job, pe_id) == "retired":
                    raise
                self.retries += 1
                attempt += 1
                time.sleep(self._backoff(attempt - 1))
        if self.fabric.epoch == self._epoch:
            # only cache if no binding moved while we resolved
            self._queues[key] = q
        return q

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "retries": self.retries,
                "entries": len(self._queues)}
