"""Data-plane fabric: queues ("TCP"), name resolution ("DNS"), collectives
("ICI") and checkpoint/abort epochs.

The platform (controllers/conductors) never touches tuple or tensor data —
exactly the paper's control/data-plane separation (§8 discussion).  PEs find
each other through ``resolve`` (with a configurable propagation delay that
reproduces the paper's DNS-latency observations), stream tuples over bounded
queues, and data-parallel trainer shards combine gradients through
``CollectiveGroup`` — the stand-in for ICI all-reduce, which on real
hardware belongs to XLA, not the platform.

``CollectiveGroup`` supports *epoch aborts*: when the consistent-region
operator initiates rollback-and-recovery, in-flight barriers abort with
``EpochAborted`` so surviving shards rewind to the committed checkpoint
instead of deadlocking on a dead peer.
"""

from __future__ import annotations

import queue
import threading
import time


class EpochAborted(Exception):
    def __init__(self, epoch: int):
        super().__init__(f"collective epoch aborted -> {epoch}")
        self.epoch = epoch


class ShutDown(Exception):
    pass


class TupleQueue:
    """Bounded blocking queue standing in for a PE-PE TCP connection.

    Instrumented for the metrics plane: cumulative enqueue/dequeue counters,
    a depth high-watermark, and a count of puts that found the queue full
    (the backpressure signal autoscaling acts on).
    """

    def __init__(self, maxsize: int = 1024):
        self._q = queue.Queue(maxsize=maxsize)
        self.capacity = maxsize
        self.closed = False
        self.enqueued = 0
        self.dequeued = 0
        self.high_watermark = 0
        self.blocked_puts = 0

    def put(self, item, timeout: float = 10.0) -> None:
        if self._q.full():
            self.blocked_puts += 1
        self._q.put(item, timeout=timeout)
        self.enqueued += 1
        depth = self._q.qsize()
        if depth > self.high_watermark:
            self.high_watermark = depth

    def get(self, timeout: float = 0.2):
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        self.dequeued += 1
        return item

    def drain(self) -> None:
        try:
            while True:
                self._q.get_nowait()
                self.dequeued += 1
        except queue.Empty:
            pass

    def stats(self) -> dict:
        depth = self._q.qsize()
        return {"depth": depth, "capacity": self.capacity,
                "fill": depth / self.capacity if self.capacity else 0.0,
                "enqueued": self.enqueued, "dequeued": self.dequeued,
                "highWatermark": self.high_watermark,
                "blockedPuts": self.blocked_puts}

    def __len__(self):
        return self._q.qsize()


class CollectiveGroup:
    """Barrier-average over ``width`` contributors with abortable epochs."""

    def __init__(self, width: int):
        self.width = width
        self.epoch = 0
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._contrib: dict = {}  # key -> list of values
        self._result: dict = {}

    def allreduce_mean(self, key, value, epoch: int, timeout: float = 30.0,
                       rank: int = 0):
        """Blocks until all ``width`` shards contribute (same epoch).

        Contributions are summed in ``rank`` order so the float reduction is
        deterministic regardless of thread arrival order — what makes
        recovered training bit-identical to an uninterrupted run."""
        import numpy as np

        with self._cond:
            if epoch != self.epoch:
                raise EpochAborted(self.epoch)
            bucket = self._contrib.setdefault((epoch, key), [])
            bucket.append((rank, value))
            if len(bucket) == self.width:
                arrs = [v for _, v in sorted(bucket, key=lambda rv: rv[0])]
                self._result[(epoch, key)] = [
                    sum(np.asarray(a[i], dtype=np.float32) for a in arrs) / self.width
                    for i in range(len(arrs[0]))
                ]
                self._cond.notify_all()
            deadline = time.monotonic() + timeout
            while (epoch, key) not in self._result:
                if epoch != self.epoch:
                    raise EpochAborted(self.epoch)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"collective {key} timed out")
                self._cond.wait(timeout=min(remaining, 0.1))
            res = self._result[(epoch, key)]
            bucket = self._contrib.get((epoch, key))
            if bucket is not None:
                bucket.pop()
                if not bucket:
                    # last leaver cleans up
                    self._contrib.pop((epoch, key), None)
                    self._result.pop((epoch, key), None)
            return res

    def abort(self) -> int:
        with self._cond:
            self.epoch += 1
            self._contrib.clear()
            self._result.clear()
            self._cond.notify_all()
            return self.epoch


class Fabric:
    """Cluster-wide connection registry + DNS + collectives."""

    def __init__(self, dns_delay: float = 0.0):
        self._lock = threading.Lock()
        self._endpoints: dict = {}  # (job, pe_id, port_id) -> TupleQueue
        self._published_at: dict = {}
        self._collectives: dict = {}  # (job, region) -> CollectiveGroup
        self.dns_delay = dns_delay

    def publish(self, job: str, pe_id: int, port_id: int, q: TupleQueue) -> None:
        with self._lock:
            self._endpoints[(job, pe_id, port_id)] = q
            self._published_at[(job, pe_id, port_id)] = time.monotonic()

    def unpublish_pe(self, job: str, pe_id: int) -> None:
        with self._lock:
            for key in list(self._endpoints):
                if key[:2] == (job, pe_id):
                    del self._endpoints[key]
                    self._published_at.pop(key, None)

    def resolve(self, job: str, pe_id: int, port_id: int,
                timeout: float = 30.0):
        """Name resolution with propagation delay (paper §8: DNS latency)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                q = self._endpoints.get((job, pe_id, port_id))
                ts = self._published_at.get((job, pe_id, port_id), 0.0)
            if q is not None and time.monotonic() >= ts + self.dns_delay:
                return q
            time.sleep(0.002)
        raise TimeoutError(f"resolve({job}, pe {pe_id}, port {port_id})")

    def collective(self, job: str, region: str, width: int) -> CollectiveGroup:
        with self._lock:
            key = (job, region)
            grp = self._collectives.get(key)
            if grp is None or grp.width != width:
                grp = CollectiveGroup(width)
                self._collectives[key] = grp
            return grp

    def abort_collectives(self, job: str) -> None:
        with self._lock:
            groups = [g for (j, _), g in self._collectives.items() if j == job]
        for g in groups:
            g.abort()
