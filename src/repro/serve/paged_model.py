"""Paged-cache execution of the decoder LM: mixed prefill/decode ticks.

``init_paged_state`` mirrors ``models.lm.init_cache`` but replaces every
global-attention layer's ``(B, max_len, KV, D)`` cache with a shared block
pool ``(num_blocks, block_size, KV, D)`` — sequences address it through a
per-slot block table, so cache memory is proportional to tokens actually
held, not ``slots x max_len``.  Non-attention state (sliding-window ring
buffers, recurrent states) stays per-slot: it is O(window) / O(1) per
sequence and gains nothing from paging.

``make_paged_tick`` builds the engine's one jitted step: a ``lax.scan``
over up to ``C`` micro-steps in which every active slot advances by its
own number of tokens (``counts``).  Decoding slots advance one sampled
token (count 1); prefilling slots consume up to a whole prompt chunk —
chunked prefill interleaved with decode in a single batched program, which
replaces the fixed-slot engine's O(prompt) per-token admit/merge loop and
bounds the tail-latency impact of admission on running requests to
``C - 1`` masked micro-steps.

Block 0 of every pool is scratch: inactive rows write there and mask their
outputs, so no per-slot control flow exists inside the program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.lm import (
    ModelOptions,
    _decode_layer,
    _init_layer_state,
    _mask_padded_vocab,
    stack_plan,
)
from ..models.layers import apply_rope, mlp_apply, rmsnorm, rope_table
from ..models.layers import decode_attention as decode_attention_jnp
from ..models.moe import moe_apply
from ..kernels.decode_attention import paged_decode_attention


def _is_paged(spec) -> bool:
    """Global-attention layers page through the block pool; everything
    else (local ring buffers, recurrences) keeps per-slot state."""
    return spec.kind == "attn"


def _init_entry(cfg, spec, max_active, num_blocks, block_size, dtype):
    if _is_paged(spec):
        return {
            "k": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
        }
    # _init_layer_state only uses max_len to clamp the local window
    return _init_layer_state(cfg, spec, max_active, cfg.window or 1, dtype)


def init_paged_state(cfg, max_active: int, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> dict:
    """Same pytree skeleton as ``init_cache`` (prefix/main/tail/len), with
    attn entries pool-shaped.  ``len`` is per-slot tokens in context."""
    plan = stack_plan(cfg)
    state = {
        "prefix": [_init_entry(cfg, s, max_active, num_blocks, block_size,
                               dtype) for s in plan.prefix],
        "tail": [_init_entry(cfg, s, max_active, num_blocks, block_size,
                             dtype) for s in plan.tail],
        "len": jnp.zeros((max_active,), jnp.int32),
    }
    if plan.num_groups:
        one = [_init_entry(cfg, s, max_active, num_blocks, block_size, dtype)
               for s in plan.pattern]
        state["main"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None],
                                       (plan.num_groups,) + x.shape).copy(),
            one)
    else:
        state["main"] = []
    return state


def all_attention(cfg) -> bool:
    """True when every layer is global attention — the precondition for
    prefix-cache reuse (recurrent/windowed state at a cut point cannot be
    reconstructed from shared KV blocks alone)."""
    plan = stack_plan(cfg)
    return all(_is_paged(s) for s in
               list(plan.prefix) + list(plan.pattern) + list(plan.tail))


def _mask_tree(new, old, adv):
    """Keep ``old`` rows where ``adv`` is False (per-slot state leaves all
    lead with the slot axis)."""
    def pick(n, o):
        a = adv.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree.map(pick, new, old)


def _paged_attn_layer(lparams, cfg, spec, state, x, sin, cos, lengths, adv,
                      tables, opts, attn_impl, interpret):
    """The attn branch of ``lm._decode_layer`` against the block pool."""
    dt = x.dtype
    h = rmsnorm(x, lparams["norm1"]["scale"], cfg.norm_eps)
    ap = lparams["attn"]
    q = jnp.einsum("bd,dhe->bhe", h, ap["wq"].astype(dt))
    k = jnp.einsum("bd,dhe->bhe", h, ap["wk"].astype(dt))
    v = jnp.einsum("bd,dhe->bhe", h, ap["wv"].astype(dt))
    if "bq" in ap:
        q, k, v = (q + ap["bq"].astype(dt), k + ap["bk"].astype(dt),
                   v + ap["bv"].astype(dt))
    if "q_norm" in ap:
        q = rmsnorm(q, ap["q_norm"]["scale"], cfg.norm_eps)
        k = rmsnorm(k, ap["k_norm"]["scale"], cfg.norm_eps)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    bs = state["k"].shape[1]
    bidx = jnp.arange(x.shape[0])
    # inactive rows write the scratch block (0, 0); their output is
    # ignored by the caller, so no gather/scatter is ever masked out
    blk = jnp.where(adv, tables[bidx, lengths // bs], 0)
    off = jnp.where(adv, lengths % bs, 0)
    new_k = state["k"].at[blk, off].set(k)
    new_v = state["v"].at[blk, off].set(v)

    if attn_impl == "kernel":
        out = paged_decode_attention(q, new_k, new_v, tables, lengths + 1,
                                     interpret=interpret)
    else:  # pure-jnp gather: XLA materializes each slot's view on gather
        B = x.shape[0]
        KV, D = new_k.shape[2], new_k.shape[3]
        kc = new_k[tables].reshape(B, -1, KV, D)
        vc = new_v[tables].reshape(B, -1, KV, D)
        out = decode_attention_jnp(q, kc, vc, lengths + 1)
    mix = jnp.einsum("bhe,hed->bd", out, ap["wo"].astype(dt))
    x = x + mix
    if spec.use_moe:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        out2, _ = moe_apply(lparams["moe"], h2[:, None, :], cfg.moe, cfg.act)
        x = x + out2[:, 0]
    elif spec.d_ff > 0:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_apply(lparams["mlp"], h2, cfg.act, cfg.gated_mlp)
    return x, {"k": new_k, "v": new_v}


def _paged_layer(lparams, cfg, spec, state, x, sin, cos, lengths, adv,
                 tables, opts, attn_impl, interpret):
    if _is_paged(spec):
        return _paged_attn_layer(lparams, cfg, spec, state, x, sin, cos,
                                 lengths, adv, tables, opts, attn_impl,
                                 interpret)
    x2, ns = _decode_layer(lparams, cfg, spec, state, x, sin, cos, lengths,
                           opts)
    return x2, _mask_tree(ns, state, adv)


def _paged_decode_step(params, cfg, state, tables, tokens, adv, opts,
                       attn_impl, interpret):
    """One token for every advancing slot: ``lm.decode_step`` against the
    paged state.  tokens/adv (B,); tables (B, T) int32."""
    plan = stack_plan(cfg)
    dt = opts.dtype
    lengths = state["len"]
    x = params["embed"]["table"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    sin, cos = rope_table(lengths, cfg.head_dim, cfg.rope_theta)
    new_state = {"len": jnp.where(adv, lengths + 1, lengths),
                 "prefix": [], "tail": [], "main": state["main"]}

    for lp, spec, st in zip(params["prefix"], plan.prefix, state["prefix"]):
        x, ns = _paged_layer(lp, cfg, spec, st, x, sin, cos, lengths, adv,
                             tables, opts, attn_impl, interpret)
        new_state["prefix"].append(ns)

    if plan.num_groups:
        def group_body(x, scanned):
            group_params, group_state = scanned
            new_states = []
            for i, spec in enumerate(plan.pattern):
                x, ns = _paged_layer(group_params[i], cfg, spec,
                                     group_state[i], x, sin, cos, lengths,
                                     adv, tables, opts, attn_impl, interpret)
                new_states.append(ns)
            return x, new_states

        x, new_main = jax.lax.scan(group_body, x,
                                   (params["main"], state["main"]))
        new_state["main"] = new_main

    for lp, spec, st in zip(params["tail"], plan.tail, state["tail"]):
        x, ns = _paged_layer(lp, cfg, spec, st, x, sin, cos, lengths, adv,
                             tables, opts, attn_impl, interpret)
        new_state["tail"].append(ns)

    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"]["w"])
    logits = jnp.einsum("bd,dv->bv", x, head.astype(dt),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return _mask_padded_vocab(logits, cfg), new_state


def make_paged_tick(cfg, opts: ModelOptions = ModelOptions(), *,
                    attn_impl: str = "gather", interpret: bool = False):
    """Build the engine's jitted mixed tick.

    ``tick(params, state, tables, feed, counts, active)`` runs
    ``feed.shape[1]`` micro-steps; slot ``b`` advances through
    ``feed[b, :counts[b]]`` (masked no-op afterwards) and the returned
    logits row is the one produced by its *last* advanced token — the
    sampling point for decode slots and the first-token logits for slots
    that just finished prefill.  The state is donated: callers must adopt
    the returned state and drop the argument.
    """

    def tick(params, state, tables, feed, counts, active):
        B, C = feed.shape
        last = jnp.zeros((B, cfg.padded_vocab), jnp.float32)

        def micro(carry, i):
            state, last = carry
            adv = active & (i < counts)
            logits, state = _paged_decode_step(params, cfg, state, tables,
                                               feed[:, i], adv, opts,
                                               attn_impl, interpret)
            sel = active & (i == counts - 1)
            last = jnp.where(sel[:, None], logits, last)
            return (state, last), None

        (state, last), _ = jax.lax.scan(micro, (state, last),
                                        jnp.arange(C, dtype=jnp.int32))
        return last, state

    return jax.jit(tick)


def make_copy_block(cfg):
    """Jitted pool-slab copy ``src -> dst`` across every paged layer — the
    device half of copy-on-write (the allocator decides *when*)."""
    plan = stack_plan(cfg)

    def copy_entry(spec, entry, src, dst, stacked):
        if not _is_paged(spec):
            return entry
        if stacked:  # scanned main group: leading group axis
            return {k: p.at[:, dst].set(p[:, src]) for k, p in entry.items()}
        return {k: p.at[dst].set(p[src]) for k, p in entry.items()}

    def copy(state, src, dst):
        out = {"len": state["len"]}
        out["prefix"] = [copy_entry(s, e, src, dst, False)
                         for s, e in zip(plan.prefix, state["prefix"])]
        out["tail"] = [copy_entry(s, e, src, dst, False)
                       for s, e in zip(plan.tail, state["tail"])]
        if plan.num_groups:
            out["main"] = [copy_entry(s, e, src, dst, True)
                           for s, e in zip(plan.pattern, state["main"])]
        else:
            out["main"] = []
        return out

    return jax.jit(copy)


def make_reset_slot(cfg):
    """Jitted per-slot reset for admission: zero the slot's rows of every
    *per-slot* (non-paged) state leaf and seed its length with the number
    of prefix-cached tokens it adopts.  Paged pools need no reset — block
    contents beyond a sequence's length are masked by construction."""
    plan = stack_plan(cfg)

    def reset_entry(spec, entry, slot, stacked):
        if _is_paged(spec):
            return entry

        def zero(x):
            if stacked:
                return x.at[:, slot].set(jnp.zeros_like(x[:, slot]))
            return x.at[slot].set(jnp.zeros_like(x[slot]))

        return jax.tree.map(zero, entry)

    def reset(state, slot, n_tokens):
        out = {"len": state["len"].at[slot].set(n_tokens)}
        out["prefix"] = [reset_entry(s, e, slot, False)
                         for s, e in zip(plan.prefix, state["prefix"])]
        out["tail"] = [reset_entry(s, e, slot, False)
                       for s, e in zip(plan.tail, state["tail"])]
        if plan.num_groups:
            out["main"] = [reset_entry(s, e, slot, True)
                           for s, e in zip(plan.pattern, state["main"])]
        else:
            out["main"] = []
        return out

    return jax.jit(reset)
