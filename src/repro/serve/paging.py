"""Paged KV-cache bookkeeping: block allocator, per-sequence tables, prefix cache.

The serving engine stores KV state in fixed-size *blocks* (``block_size``
tokens each) drawn from a shared pool, so a request only ever holds memory
proportional to its actual length — no ``max_len`` padding.  This module is
the pure-Python control plane for that pool:

- ``BlockAllocator``: free-list allocation with per-block reference counts.
  Physical block 0 is reserved as the scratch block (inactive batch rows
  write there; it is never handed out), so a block table full of zeros is
  always safe to index on-device.
- ``SequenceBlocks``: one request's logical->physical block list plus its
  token length.  Appending tokens allocates on block boundaries;
  ``ensure_writable`` performs copy-on-write when the write position lands
  in a block shared with the prefix cache or another request.
- ``PrefixCache``: a radix tree over *block-granular* token chunks.  Full
  prompt blocks are committed after prefill and shared (refcounted) across
  requests with the same prefix; a partially filled tail block may also be
  shared, in which case the adopting request copies it on first write
  (divergence).  Eviction is LRU over unreferenced leaves.

Everything here is host-side metadata; the device-side pools and the
gather/compute over them live in ``paged_model.py`` / the Pallas kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field


class OutOfBlocks(RuntimeError):
    """The pool has no free blocks (after cache eviction was attempted)."""


class BlockAllocator:
    """Fixed-pool block allocator: free list + per-block refcounts.

    Block ids run ``1..num_blocks-1``; block 0 is the reserved scratch
    block inactive device rows write into, so it is never allocated and
    never freed.  ``capacity`` is therefore ``num_blocks - 1``.
    """

    SCRATCH = 0

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is scratch)")
        if block_size < 1:
            raise ValueError("block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently freed blocks are re-used first (their
        # pool slabs are warm)
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref = [0] * num_blocks

    # ------------------------------------------------------------- queries

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_used(self) -> int:
        return self.capacity - len(self._free)

    def ref(self, block: int) -> int:
        return self._ref[block]

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size) if n_tokens > 0 else 0

    # ----------------------------------------------------------- lifecycle

    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocks(f"all {self.capacity} blocks in use")
        block = self._free.pop()
        assert self._ref[block] == 0
        self._ref[block] = 1
        return block

    def incref(self, block: int) -> None:
        if block == self.SCRATCH or self._ref[block] == 0:
            raise ValueError(f"incref on unowned block {block}")
        self._ref[block] += 1

    def decref(self, block: int) -> None:
        if block == self.SCRATCH or self._ref[block] == 0:
            raise ValueError(f"decref on unowned block {block}")
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)

    def cow(self, block: int) -> tuple:
        """Copy-on-write: returns ``(block, None)`` when ``block`` is
        exclusively owned (safe to write in place), else allocates a fresh
        block, drops this owner's reference on the shared one, and returns
        ``(new_block, block)`` — the caller must copy the pool slab
        ``block -> new_block`` before writing."""
        if self._ref[block] <= 1:
            return block, None
        new = self.alloc()  # may raise OutOfBlocks: caller handles
        self._ref[block] -= 1  # shared, so never drops to 0 here
        return new, block

    def check(self) -> None:
        """Free-list conservation invariant (used by the property tests)."""
        assert len(set(self._free)) == len(self._free), "duplicate free block"
        for b in self._free:
            assert self._ref[b] == 0, f"free block {b} has refs"
        assert self._ref[self.SCRATCH] == 0
        live = sum(1 for b in range(1, self.num_blocks) if self._ref[b] > 0)
        assert live + len(self._free) == self.capacity


class SequenceBlocks:
    """One request's block list + token length (owns one ref per block)."""

    def __init__(self, alloc: BlockAllocator):
        self._alloc = alloc
        self.blocks: list = []
        self.length = 0  # tokens written

    def adopt(self, blocks: list, n_tokens: int) -> None:
        """Start from a cached prefix: share ``blocks`` (already
        increffed by the cache on match) covering ``n_tokens`` tokens."""
        assert not self.blocks and self.length == 0
        self.blocks = list(blocks)
        self.length = n_tokens

    def ensure_capacity(self, n_new_tokens: int) -> list:
        """Allocate blocks so positions ``length .. length+n-1`` are
        backed; returns the newly allocated block ids (for table updates).
        Raises ``OutOfBlocks`` without partial allocation."""
        need = self._alloc.blocks_for_tokens(self.length + n_new_tokens)
        extra = need - len(self.blocks)
        if extra <= 0:
            return []
        if extra > self._alloc.blocks_free:
            raise OutOfBlocks(f"need {extra} blocks, "
                              f"{self._alloc.blocks_free} free")
        new = [self._alloc.alloc() for _ in range(extra)]
        self.blocks.extend(new)
        return new

    def ensure_writable(self) -> tuple:
        """Copy-on-write guard for the block the next token lands in.
        Returns ``(dst, src)``: ``src`` is ``None`` unless the engine must
        copy pool slab ``src -> dst`` (the block was shared)."""
        idx = self.length // self._alloc.block_size
        if idx >= len(self.blocks):
            return None, None  # next write opens a fresh block
        dst, src = self._alloc.cow(self.blocks[idx])
        if src is not None:
            self.blocks[idx] = dst
        return dst, src

    def free(self) -> None:
        for b in self.blocks:
            self._alloc.decref(b)
        self.blocks = []
        self.length = 0


@dataclass
class _PrefixNode:
    """One cached block: keyed by its token chunk, linked radix-style."""

    tokens: tuple  # the block's token contents (len == fill)
    block: int
    fill: int  # tokens valid in the block (== block_size unless tail)
    parent: object = None
    children: dict = field(default_factory=dict)  # full-block chunks only
    tail: object = None  # at most one partial-tail child
    stamp: int = 0  # LRU clock


class PrefixCache:
    """Block-granular radix cache over committed prompt blocks.

    ``match`` walks full-block children and may finish on a shared partial
    tail; ``insert`` commits a finished prompt's blocks (increffing them on
    behalf of the cache); ``evict`` frees least-recently-used leaves whose
    blocks nobody else references.  The cache owns exactly one reference
    per cached block, so engine-side sequence frees never invalidate it.
    """

    def __init__(self, alloc: BlockAllocator):
        self._alloc = alloc
        self._root = _PrefixNode((), BlockAllocator.SCRATCH, 0)
        self._clock = 0
        self.blocks_cached = 0
        # hit accounting (engine-visible signals)
        self.lookups = 0
        self.hits = 0
        self.tokens_matched = 0

    def _touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.stamp = self._clock

    def match(self, tokens: list) -> tuple:
        """Longest cached prefix of ``tokens``.

        Returns ``(blocks, n_tokens, tail_shared)``; every returned block
        has been increffed for the caller (adopt them into a
        ``SequenceBlocks``).  ``tail_shared`` is True when the last block
        is a partially-filled shared tail — the adopter must copy-on-write
        before appending.  At most ``len(tokens) - 1`` tokens are matched
        so a fully cached prompt still computes its final-token logits."""
        self.lookups += 1
        bs = self._alloc.block_size
        usable = max(len(tokens) - 1, 0)
        node, blocks, n = self._root, [], 0
        while n + bs <= usable:
            child = node.children.get(tuple(tokens[n:n + bs]))
            if child is None:
                break
            node, n = child, n + bs
            blocks.append(child.block)
            self._touch(child)
        tail_shared = False
        if node.tail is not None:
            t = node.tail
            take = min(t.fill, usable - n)
            if take > 0 and tuple(tokens[n:n + take]) == t.tokens[:take]:
                blocks.append(t.block)
                n += take
                tail_shared = True
                self._touch(t)
        for b in blocks:
            self._alloc.incref(b)
        if n:
            self.hits += 1
        self.tokens_matched += n
        return blocks, n, tail_shared

    def insert(self, tokens: list, blocks: list, n_tokens: int) -> int:
        """Commit a prefilled prompt's blocks: ``tokens[:n_tokens]`` living
        in ``blocks``.  Already-cached levels are left alone (the first
        committer wins; the caller keeps its own duplicate blocks).
        Returns the number of blocks newly cached."""
        bs = self._alloc.block_size
        node, added, i = self._root, 0, 0
        while (i + 1) * bs <= n_tokens:
            chunk = tuple(tokens[i * bs:(i + 1) * bs])
            child = node.children.get(chunk)
            if child is None:
                child = _PrefixNode(chunk, blocks[i], bs, parent=node)
                self._alloc.incref(blocks[i])
                node.children[chunk] = child
                added += 1
                self.blocks_cached += 1
            node = child
            self._touch(node)
            i += 1
        fill = n_tokens - i * bs
        if fill > 0 and node.tail is None and i < len(blocks):
            node.tail = _PrefixNode(tuple(tokens[i * bs:n_tokens]),
                                    blocks[i], fill, parent=node)
            self._alloc.incref(blocks[i])
            added += 1
            self.blocks_cached += 1
            self._touch(node.tail)
        return added

    # ------------------------------------------------------------ eviction

    def _leaves(self) -> list:
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.tail is not None:
                stack.append(node.tail)
            if node is not self._root and not node.children and node.tail is None:
                out.append(node)
        return out

    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` LRU leaf blocks nobody else references.
        Returns how many were released to the allocator."""
        released = 0
        while released < n_blocks:
            victims = [lf for lf in self._leaves()
                       if self._alloc.ref(lf.block) == 1]
            if not victims:
                break
            leaf = min(victims, key=lambda lf: lf.stamp)
            parent = leaf.parent
            if parent.tail is leaf:
                parent.tail = None
            else:
                del parent.children[leaf.tokens]
            self._alloc.decref(leaf.block)
            self.blocks_cached -= 1
            released += 1
        return released

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0
