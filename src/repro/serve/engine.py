"""Serving: prefill + decode steps and a continuous-batching engine.

``make_prefill_step`` / ``make_decode_step`` build the jit-able pure
functions the dry-run lowers for the inference shapes.  ``ServeEngine`` is a
small continuous-batching driver used by the serving example and the
platform's serving jobs: it keeps a fixed batch of slots, admits new
requests into free slots (prefilling them), and steps the whole batch one
token at a time, retiring finished requests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import ModelOptions, decode_step, forward_with_cache, init_cache
from ..sharding.ctx import use_rules


def make_prefill_step(cfg: ArchConfig, opts: ModelOptions = ModelOptions(),
                      max_len: int = 0, mesh=None, act_rules=None):
    def prefill(params, batch):
        ctx = use_rules(mesh, act_rules) if (mesh is not None and act_rules) else None
        if ctx is not None:
            with ctx:
                return forward_with_cache(params, cfg, batch["tokens"],
                                          batch.get("frontend_embeds"),
                                          max_len=max_len, opts=opts)
        return forward_with_cache(params, cfg, batch["tokens"],
                                  batch.get("frontend_embeds"),
                                  max_len=max_len, opts=opts)

    return prefill


def make_decode_step(cfg: ArchConfig, opts: ModelOptions = ModelOptions(),
                     mesh=None, act_rules=None):
    def step(params, cache, tokens):
        ctx = use_rules(mesh, act_rules) if (mesh is not None and act_rules) else None
        if ctx is not None:
            with ctx:
                return decode_step(params, cfg, cache, tokens, opts)
        return decode_step(params, cfg, cache, tokens, opts)

    return step


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed slot count (single-host driver).

    Admission prefills a request into a free slot by re-running the batched
    prefill with the slot's row swapped in (slot caches are batch rows of the
    shared cache pytree).  Greedy decoding; per-slot lengths.
    """

    def __init__(self, cfg: ArchConfig, params, num_slots: int, max_len: int,
                 opts: ModelOptions = ModelOptions()):
        self.cfg = cfg
        self.params = params
        self.opts = opts
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, num_slots, max_len,
                                dtype=opts.dtype if opts.compute_dtype != "float32"
                                else jnp.float32)
        self.slots: list = [None] * num_slots
        self.queue: list = []
        self.finished: list = []
        self._decode = jax.jit(make_decode_step(cfg, opts))
        self._next_token = jnp.zeros((num_slots,), jnp.int32)
        # slot-occupancy metrics (the serving load signal the platform's
        # metrics plane aggregates, so serving jobs can autoscale too)
        self.ticks = 0
        self.tokens_generated = 0
        self._busy_ticks = 0
        self.on_metrics: Optional[Callable[[dict], None]] = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                # reset the slot's cache row and feed the prompt token by token
                self.cache = _reset_slot(self.cache, slot)
                tok = self._next_token
                for t in req.prompt:
                    tok = tok.at[slot].set(t)
                    logits, self.cache = self._decode_one_slot(slot, tok)
                self._next_token = self._next_token.at[slot].set(
                    int(jnp.argmax(logits[slot])))

    def _decode_one_slot(self, slot: int, tokens):
        # mask: only this slot advances during admission; other slots' len
        # must not change.  We run the batched step but restore other rows.
        before = self.cache
        logits, after = self._decode(self.params, self.cache, tokens)
        self.cache = _merge_slot(before, after, slot)
        return logits, self.cache

    def metrics(self) -> dict:
        """Slot occupancy + queue state: the engine's scaling signals.

        ``occupancy`` is instantaneous (busy slots / slots); ``backpressure``
        is the admission queue normalized by slot count — >0 means requests
        are waiting for a slot, the cue to add replicas.
        """
        busy = sum(1 for s in self.slots if s is not None)
        return {
            "numSlots": self.num_slots, "slotsBusy": busy,
            "occupancy": busy / self.num_slots,
            "meanOccupancy": (self._busy_ticks / (self.ticks * self.num_slots)
                              if self.ticks else 0.0),
            "queueDepth": len(self.queue),
            "backpressure": min(1.0, len(self.queue) / self.num_slots),
            "ticks": self.ticks, "tokensGenerated": self.tokens_generated,
            "finished": len(self.finished),
        }

    def step(self) -> list:
        """One engine tick: admit, decode one token for all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        self.ticks += 1
        self._busy_ticks += len(active)
        if self.on_metrics is not None:
            self.on_metrics(self.metrics())
        if not active:
            return []
        logits, self.cache = self._decode(self.params, self.cache, self._next_token)
        out = []
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
            out.append((req.rid, tok))
        self.tokens_generated += len(out)
        self._next_token = nxt
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> list:
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished


def _is_stacked(path) -> bool:
    """Leaves under cache['main'] carry a leading scanned-group dim; the
    batch dim is axis 1 there, axis 0 elsewhere.  Decide by path, not by
    shape — group count can collide with the slot count."""
    from jax.tree_util import DictKey

    for p in path:
        if isinstance(p, DictKey):
            return p.key == "main"
    return False


def _reset_slot(cache, slot: int):
    def zero_row(path, x):
        if _is_stacked(path):
            return x.at[:, slot].set(jnp.zeros_like(x[:, slot]))
        if x.ndim >= 1:
            return x.at[slot].set(jnp.zeros_like(x[slot]))
        return x

    new = jax.tree_util.tree_map_with_path(zero_row, cache)
    new["len"] = cache["len"].at[slot].set(0)
    return new


def _merge_slot(before, after, slot: int):
    """Take ``after``'s row ``slot``; keep ``before`` elsewhere."""

    def merge(path, b, a):
        if _is_stacked(path):
            return b.at[:, slot].set(a[:, slot])
        if b.ndim >= 1:
            return b.at[slot].set(a[slot])
        return b

    out = jax.tree_util.tree_map_with_path(merge, before, after)
    out["len"] = before["len"].at[slot].set(after["len"][slot])
    return out
