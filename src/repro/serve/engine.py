"""Serving: prefill + decode steps and the continuous-batching engines.

``make_prefill_step`` / ``make_decode_step`` build the jit-able pure
functions the dry-run lowers for the inference shapes.  ``ServeEngine`` is
the fixed-slot continuous-batching driver: it pads every slot's cache to
``max_len`` and admits prompts one token at a time through full-cache
merges — kept as the baseline the serve benchmark measures against.

``PagedServeEngine`` is the production path: KV lives in fixed-size blocks
handed out by a free-list allocator (``paging.py``), so admission capacity
scales with tokens actually held instead of ``slots x max_len``; prompt
admission runs as *chunked prefill* interleaved with decode inside one
jitted mixed tick (``paged_model.py``); and committed prompt blocks are
shared across requests through a refcounted prefix cache with
copy-on-write on divergence.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import ModelOptions, decode_step, forward_with_cache, init_cache
from ..sharding.ctx import use_rules
from .paged_model import (
    all_attention,
    init_paged_state,
    make_copy_block,
    make_paged_tick,
    make_reset_slot,
)
from .paging import BlockAllocator, OutOfBlocks, PrefixCache, SequenceBlocks


def make_prefill_step(cfg: ArchConfig, opts: ModelOptions = ModelOptions(),
                      max_len: int = 0, mesh=None, act_rules=None):
    def prefill(params, batch):
        ctx = use_rules(mesh, act_rules) if (mesh is not None and act_rules) else None
        if ctx is not None:
            with ctx:
                return forward_with_cache(params, cfg, batch["tokens"],
                                          batch.get("frontend_embeds"),
                                          max_len=max_len, opts=opts)
        return forward_with_cache(params, cfg, batch["tokens"],
                                  batch.get("frontend_embeds"),
                                  max_len=max_len, opts=opts)

    return prefill


def make_decode_step(cfg: ArchConfig, opts: ModelOptions = ModelOptions(),
                     mesh=None, act_rules=None):
    def step(params, cache, tokens):
        ctx = use_rules(mesh, act_rules) if (mesh is not None and act_rules) else None
        if ctx is not None:
            with ctx:
                return decode_step(params, cfg, cache, tokens, opts)
        return decode_step(params, cfg, cache, tokens, opts)

    return step


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed slot count (single-host driver).

    Admission prefills a request into a free slot by re-running the batched
    prefill with the slot's row swapped in (slot caches are batch rows of the
    shared cache pytree).  Greedy decoding; per-slot lengths.
    """

    def __init__(self, cfg: ArchConfig, params, num_slots: int, max_len: int,
                 opts: ModelOptions = ModelOptions()):
        self.cfg = cfg
        self.params = params
        self.opts = opts
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, num_slots, max_len,
                                dtype=opts.dtype if opts.compute_dtype != "float32"
                                else jnp.float32)
        self.slots: list = [None] * num_slots
        self.queue: deque = deque()  # popleft() is O(1); a list's pop(0) is O(n)
        self.finished: list = []
        self._decode = jax.jit(make_decode_step(cfg, opts))
        self._next_token = jnp.zeros((num_slots,), jnp.int32)
        # slot-occupancy metrics (the serving load signal the platform's
        # metrics plane aggregates, so serving jobs can autoscale too).
        # slots_busy is maintained incrementally on admit/retire so
        # metrics() never rescans the slot list per tick.
        self.ticks = 0
        self.tokens_generated = 0
        self.slots_busy = 0
        self._busy_ticks = 0
        self.on_metrics: Optional[Callable[[dict], None]] = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.slots[slot] is None and self.queue:
                req = self.queue.popleft()
                self.slots[slot] = req
                self.slots_busy += 1
                # reset the slot's cache row and feed the prompt token by token
                self.cache = _reset_slot(self.cache, slot)
                tok = self._next_token
                for t in req.prompt:
                    tok = tok.at[slot].set(t)
                    logits, self.cache = self._decode_one_slot(slot, tok)
                self._next_token = self._next_token.at[slot].set(
                    int(jnp.argmax(logits[slot])))

    def _decode_one_slot(self, slot: int, tokens):
        # mask: only this slot advances during admission; other slots' len
        # must not change.  We run the batched step but restore other rows.
        before = self.cache
        logits, after = self._decode(self.params, self.cache, tokens)
        self.cache = _merge_slot(before, after, slot)
        return logits, self.cache

    def metrics(self) -> dict:
        """Slot occupancy + queue state: the engine's scaling signals.

        ``occupancy`` is instantaneous (busy slots / slots); ``backpressure``
        is the admission queue normalized by slot count — >0 means requests
        are waiting for a slot, the cue to add replicas.
        """
        busy = self.slots_busy
        return {
            "numSlots": self.num_slots, "slotsBusy": busy,
            "occupancy": busy / self.num_slots,
            "meanOccupancy": (self._busy_ticks / (self.ticks * self.num_slots)
                              if self.ticks else 0.0),
            "queueDepth": len(self.queue),
            "backpressure": min(1.0, len(self.queue) / self.num_slots),
            "ticks": self.ticks, "tokensGenerated": self.tokens_generated,
            "finished": len(self.finished),
        }

    def step(self) -> list:
        """One engine tick: admit, decode one token for all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        self.ticks += 1
        self._busy_ticks += len(active)
        if self.on_metrics is not None:
            self.on_metrics(self.metrics())
        if not active:
            return []
        logits, self.cache = self._decode(self.params, self.cache, self._next_token)
        out = []
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
                self.slots_busy -= 1
            out.append((req.rid, tok))
        self.tokens_generated += len(out)
        self._next_token = nxt
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> list:
        ticks = 0
        while (self.queue or self.slots_busy) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished


def _is_stacked(path) -> bool:
    """Leaves under cache['main'] carry a leading scanned-group dim; the
    batch dim is axis 1 there, axis 0 elsewhere.  Decide by path, not by
    shape — group count can collide with the slot count."""
    from jax.tree_util import DictKey

    for p in path:
        if isinstance(p, DictKey):
            return p.key == "main"
    return False


def _reset_slot(cache, slot: int):
    def zero_row(path, x):
        if _is_stacked(path):
            return x.at[:, slot].set(jnp.zeros_like(x[:, slot]))
        if x.ndim >= 1:
            return x.at[slot].set(jnp.zeros_like(x[slot]))
        return x

    new = jax.tree_util.tree_map_with_path(zero_row, cache)
    new["len"] = cache["len"].at[slot].set(0)
    return new


def _merge_slot(before, after, slot: int):
    """Take ``after``'s row ``slot``; keep ``before`` elsewhere."""

    def merge(path, b, a):
        if _is_stacked(path):
            return b.at[:, slot].set(a[:, slot])
        if b.ndim >= 1:
            return b.at[slot].set(a[slot])
        return b

    out = jax.tree_util.tree_map_with_path(merge, before, after)
    out["len"] = before["len"].at[slot].set(after["len"][slot])
    return out


# ---------------------------------------------------------------- paged


@dataclass
class _PagedSlot:
    """One active request's engine-side bookkeeping."""

    req: Request
    seq: SequenceBlocks
    pos: int  # prompt tokens fed so far (== cached tokens at admission)
    next_token: int = 0  # next decode feed once prefill completed
    reserved: int = 0  # future block demand still counted in the reserve


class PagedServeEngine:
    """Continuous batching over a paged KV cache (single-host driver).

    Admission allocates blocks for the request's actual length — no
    ``max_len`` padding — after consulting the prefix cache for committed
    prompt blocks it can share (refcounted; copy-on-write on the first
    divergent write into a shared tail block).  Prompts prefill in chunks
    of ``prefill_chunk`` tokens *inside* the regular batched tick, so a
    long admission delays running decodes by at most ``prefill_chunk - 1``
    masked micro-steps instead of a full O(prompt) blocking loop.  Greedy
    decoding, same output semantics as ``ServeEngine``.
    """

    def __init__(self, cfg: ArchConfig, params, *, num_blocks: int,
                 block_size: int = 16, max_active: int = 8,
                 prefill_chunk: int = 8, opts: ModelOptions = ModelOptions(),
                 attn_impl: str = "gather", interpret: bool = False,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.opts = opts
        self.max_active = max_active
        self.prefill_chunk = max(1, prefill_chunk)
        dtype = (opts.dtype if opts.compute_dtype != "float32"
                 else jnp.float32)
        self.alloc = BlockAllocator(num_blocks, block_size)
        # prefix sharing needs every layer's state to be reconstructable
        # from shared KV blocks — only true for pure global attention
        # (recurrent/windowed state at the cut point is not in the blocks)
        self.cache = (PrefixCache(self.alloc)
                      if prefix_cache and all_attention(cfg) else None)
        self.state = init_paged_state(cfg, max_active, num_blocks,
                                      block_size, dtype)
        self._tables = np.zeros((max_active, self.alloc.capacity), np.int32)
        self._tick = make_paged_tick(cfg, opts, attn_impl=attn_impl,
                                     interpret=interpret)
        self._copy = make_copy_block(cfg)
        self._reset = make_reset_slot(cfg)
        self.slots: list = [None] * max_active
        self.queue: deque = deque()
        self.finished: list = []
        # incremental signal counters (metrics() never rescans)
        self.ticks = 0
        self.tokens_generated = 0
        self.slots_busy = 0
        self._busy_ticks = 0
        self._reserved = 0  # future block demand of active slots
        self._prefill_backlog = 0  # prompt tokens submitted, not yet fed
        self._prompt_tokens = 0  # admitted prompt tokens (hit-rate denom)
        self._cached_tokens = 0  # admitted via prefix cache (hit-rate num)
        self.cow_copies = 0
        self.peak_active = 0
        self.on_metrics: Optional[Callable[[dict], None]] = None

    # ----------------------------------------------------------- admission

    def submit(self, req: Request) -> None:
        need = self.alloc.blocks_for_tokens(
            len(req.prompt) + req.max_new_tokens)
        if need > self.alloc.capacity:
            raise ValueError(
                f"request {req.rid} needs {need} blocks; pool holds "
                f"{self.alloc.capacity}")
        self.queue.append(req)
        self._prefill_backlog += len(req.prompt)

    def _admit(self) -> None:
        while self.queue:
            slot = next((i for i, s in enumerate(self.slots) if s is None),
                        None)
            if slot is None:
                return
            req = self.queue[0]
            prompt = list(req.prompt)
            blocks, n, tail_shared = ([], 0, False)
            if self.cache is not None:
                blocks, n, tail_shared = self.cache.match(prompt)
            # banker's admission: reserve the request's *entire* footprint
            # (prompt + worst-case decode; a shared tail costs one extra —
            # its copy-on-write replacement) against free blocks minus the
            # outstanding reservations of already-running requests, so
            # growth during decode can never deadlock the pool
            required = (self.alloc.blocks_for_tokens(
                len(prompt) + req.max_new_tokens)
                - len(blocks) + (1 if tail_shared else 0))
            short = required + self._reserved - self.alloc.blocks_free
            if short > 0 and self.cache is not None:
                self.cache.evict(short)
            if required + self._reserved > self.alloc.blocks_free:
                for b in blocks:  # memory-aware admission control: wait
                    self.alloc.decref(b)
                return
            self.queue.popleft()
            seq = SequenceBlocks(self.alloc)
            seq.adopt(blocks, n)
            self.slots[slot] = _PagedSlot(req=req, seq=seq, pos=n,
                                          reserved=required)
            self._reserved += required
            self.slots_busy += 1
            self.peak_active = max(self.peak_active, self.slots_busy)
            self._prompt_tokens += len(prompt)
            self._cached_tokens += n
            self._prefill_backlog -= n  # cached tokens are never fed
            self._table_row(slot)
            # zero the slot's per-slot (non-paged) state, seed len with the
            # adopted prefix length
            self.state = self._reset(self.state, slot, n)

    def _table_row(self, slot: int) -> None:
        blocks = self.slots[slot].seq.blocks
        self._tables[slot, :len(blocks)] = blocks
        self._tables[slot, len(blocks):] = BlockAllocator.SCRATCH

    def _retire(self, slot: int) -> None:
        s = self.slots[slot]
        self._reserved -= s.reserved  # release any unused reservation
        s.seq.free()
        self._tables[slot, :] = BlockAllocator.SCRATCH
        self.state["len"] = self.state["len"].at[slot].set(0)
        self.slots[slot] = None
        self.slots_busy -= 1

    # ---------------------------------------------------------------- tick

    def _spend(self, s: _PagedSlot, n_blocks: int) -> None:
        take = min(s.reserved, n_blocks)
        s.reserved -= take
        self._reserved -= take

    def _grow(self, s: _PagedSlot, n_tokens: int) -> bool:
        """CoW guard + capacity for the next ``n_tokens`` writes; evicts
        cache blocks under pressure.  False => stall this slot one tick.
        Every block actually allocated drains the slot's admission-time
        reservation, keeping the banker's ledger exact."""
        seq = s.seq
        try:
            dst, src = seq.ensure_writable()
        except OutOfBlocks:
            if self.cache is None or not self.cache.evict(1):
                return False
            dst, src = seq.ensure_writable()
        if src is not None:
            self.state = self._copy(self.state, src, dst)
            self.cow_copies += 1
            self._spend(s, 1)
        try:
            self._spend(s, len(seq.ensure_capacity(n_tokens)))
        except OutOfBlocks:
            need = self.alloc.blocks_for_tokens(seq.length + n_tokens) \
                - len(seq.blocks)
            if self.cache is None or \
                    not self.cache.evict(need - self.alloc.blocks_free):
                return False
            try:
                self._spend(s, len(seq.ensure_capacity(n_tokens)))
            except OutOfBlocks:
                return False
        return True

    def step(self) -> list:
        """One engine tick: admit, then one mixed prefill/decode program."""
        self._admit()
        active_idx = [i for i, s in enumerate(self.slots) if s is not None]
        self.ticks += 1
        self._busy_ticks += len(active_idx)
        if self.on_metrics is not None:
            self.on_metrics(self.metrics())
        if not active_idx:
            return []
        prefilling = [i for i in active_idx
                      if self.slots[i].pos < len(self.slots[i].req.prompt)]
        C = self.prefill_chunk if prefilling else 1
        feed = np.zeros((self.max_active, C), np.int32)
        counts = np.zeros((self.max_active,), np.int32)
        active = np.zeros((self.max_active,), bool)
        issued: dict = {}
        for i in active_idx:
            s = self.slots[i]
            P = len(s.req.prompt)
            toks = (s.req.prompt[s.pos:s.pos + C] if s.pos < P
                    else [s.next_token])
            if not self._grow(s, len(toks)):
                continue  # pool exhausted: the slot stalls this tick
            self._table_row(i)
            feed[i, :len(toks)] = toks
            counts[i] = len(toks)
            active[i] = True
            issued[i] = len(toks)
            s.seq.length += len(toks)
        if not issued:
            return []
        logits, self.state = self._tick(
            self.params, self.state, jnp.asarray(self._tables),
            jnp.asarray(feed), jnp.asarray(counts), jnp.asarray(active))
        logits = np.asarray(logits)

        out = []
        for i, n in issued.items():
            s = self.slots[i]
            P = len(s.req.prompt)
            if s.pos < P:  # was prefilling
                s.pos += n
                self._prefill_backlog -= n
                if s.pos == P:
                    # prompt complete: sample the first token (fed next
                    # tick — same semantics as ServeEngine._admit) and
                    # publish the prompt's blocks for prefix reuse
                    s.next_token = int(np.argmax(logits[i]))
                    if self.cache is not None:
                        self.cache.insert(s.req.prompt, s.seq.blocks, P)
            else:
                tok = int(np.argmax(logits[i]))
                s.req.generated.append(tok)
                s.next_token = tok
                out.append((s.req.rid, tok))
                self.tokens_generated += 1
                if len(s.req.generated) >= s.req.max_new_tokens:
                    s.req.done = True
                    self.finished.append(s.req)
                    self._retire(i)
        return out

    def run_until_drained(self, max_ticks: int = 10000) -> list:
        ticks = 0
        while (self.queue or self.slots_busy) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished

    # ------------------------------------------------------------- signals

    def metrics(self) -> dict:
        """ServeEngine-shaped occupancy signals plus the paged engine's
        own: ``blocksFree``/``blocksCached`` (allocator + prefix-cache
        state), ``prefixHitRate`` (admitted prompt tokens served from
        cache), ``prefillBacklog`` (prompt tokens waiting to be fed) —
        the signals the platform's metrics plane rolls up per region."""
        return {
            "numSlots": self.max_active, "slotsBusy": self.slots_busy,
            "occupancy": self.slots_busy / self.max_active,
            "meanOccupancy": (self._busy_ticks
                              / (self.ticks * self.max_active)
                              if self.ticks else 0.0),
            "queueDepth": len(self.queue),
            "backpressure": min(1.0, len(self.queue) / self.max_active),
            "ticks": self.ticks, "tokensGenerated": self.tokens_generated,
            "finished": len(self.finished),
            "blocksTotal": self.alloc.capacity,
            "blocksFree": self.alloc.blocks_free,
            "blocksReserved": self._reserved,
            "blocksCached": (self.cache.blocks_cached
                             if self.cache is not None else 0),
            "prefixHitRate": (self._cached_tokens / self._prompt_tokens
                              if self._prompt_tokens else 0.0),
            "prefillBacklog": self._prefill_backlog,
            "cowCopies": self.cow_copies,
        }
