from .engine import (
    PagedServeEngine,
    Request,
    ServeEngine,
    make_decode_step,
    make_prefill_step,
)
from .paging import BlockAllocator, OutOfBlocks, PrefixCache, SequenceBlocks

__all__ = [
    "BlockAllocator",
    "OutOfBlocks",
    "PagedServeEngine",
    "PrefixCache",
    "Request",
    "SequenceBlocks",
    "ServeEngine",
    "make_decode_step",
    "make_prefill_step",
]
