"""Checkpoint storage for consistent regions.

Paper §6.5: operator checkpoints go to external storage (RocksDB/Redis in
the paper; the filesystem here), *never* into CRDs — the CRD records only
which checkpoint id is committed.  Layout:

    <root>/<job>/<region>/step<N>/<shard>.npz      tensor payloads
    <root>/<job>/<region>/step<N>/<shard>.json     scalars/metadata

Writes are atomic (tmp + rename).  A checkpoint is *committed* only once the
ConsistentRegion CRD's status says so; uncommitted step directories are
garbage, deleted on the next sweep — recovery state lives in exactly one
place (the CRD), everything else is recomputable or disposable.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, job: str, region: str, step: int) -> str:
        return os.path.join(self.root, job, region, f"step{step}")

    def save_shard(self, job: str, region: str, step: int, shard: str,
                   arrays=None, meta: dict | None = None) -> str:
        d = self._dir(job, region, step)
        os.makedirs(d, exist_ok=True)
        if arrays is not None:
            flat = _flatten(arrays)
            tmp = os.path.join(d, f".{shard}.npz.tmp")
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, os.path.join(d, f"{shard}.npz"))
        if meta is not None:
            tmp = os.path.join(d, f".{shard}.json.tmp")
            with open(tmp, "w") as f:
                json.dump(meta, f)
            os.replace(tmp, os.path.join(d, f"{shard}.json"))
        return d

    def load_shard(self, job: str, region: str, step: int, shard: str,
                   like=None):
        """Returns (arrays-or-unflattened, meta).  With ``like`` (a pytree),
        tensors are unflattened into its structure."""
        d = self._dir(job, region, step)
        arrays = None
        npz_path = os.path.join(d, f"{shard}.npz")
        if os.path.exists(npz_path):
            with np.load(npz_path) as z:
                flat = {k: z[k] for k in z.files}
            if like is not None:
                leaves = []
                for path, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
                    key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                   for p in path)
                    leaves.append(flat[key].astype(leaf.dtype).reshape(leaf.shape))
                arrays = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(like), leaves)
            else:
                arrays = flat
        meta = None
        json_path = os.path.join(d, f"{shard}.json")
        if os.path.exists(json_path):
            with open(json_path) as f:
                meta = json.load(f)
        return arrays, meta

    def has_shard(self, job: str, region: str, step: int, shard: str) -> bool:
        d = self._dir(job, region, step)
        return (os.path.exists(os.path.join(d, f"{shard}.npz"))
                or os.path.exists(os.path.join(d, f"{shard}.json")))

    def sweep(self, job: str, region: str, committed: int) -> int:
        """Delete uncommitted/stale step dirs (keep the committed one)."""
        base = os.path.join(self.root, job, region)
        removed = 0
        if not os.path.isdir(base):
            return 0
        for name in os.listdir(base):
            if not name.startswith("step"):
                continue
            step = int(name[4:])
            if step != committed:
                shutil.rmtree(os.path.join(base, name), ignore_errors=True)
                removed += 1
        return removed
