"""Checkpoint storage for consistent regions.

Paper §6.5: operator checkpoints go to external storage (RocksDB/Redis in
the paper; the filesystem here), *never* into CRDs — the CRD records only
which checkpoint id is committed.  Layout:

    <root>/<job>/<region>/step<N>/<shard>.npz         tensor payloads
    <root>/<job>/<region>/step<N>/<shard>.npz.sha256  payload content digest
    <root>/<job>/<region>/step<N>/<shard>.json        scalars/metadata
    <root>/<job>/<region>/step<N>/.committing         commit-in-flight marker

Writes are atomic (tmp + rename).  Checkpoints are *incremental*: given a
``base_step`` (the last committed step), a shard whose content digest is
unchanged is hard-linked from the base directory instead of rewritten, so
steady-state checkpoints cost one link per clean shard and one write per
dirty shard.  A checkpoint is *committed* only once the ConsistentRegion
CRD's status says so; strictly-older uncommitted step directories are
garbage, deleted by the conductor-driven sweep — recovery state lives in
exactly one place (the CRD), everything else is recomputable or disposable.

The ``.committing`` marker closes the commit race: it is stamped *before*
the CRD status write and cleared after, so a sweep running concurrently
with a commit can never delete the step the CRD is mid-commit on.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np

#: Commit-in-flight marker file name (see ``mark_committing``).
COMMITTING_MARKER = ".committing"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _digest(flat: dict) -> str:
    """Content digest of a flattened shard: keys, dtypes, shapes, bytes."""
    h = hashlib.sha256()
    for key in sorted(flat):
        arr = flat[key]
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class CheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, job: str, region: str, step: int) -> str:
        return os.path.join(self.root, job, region, f"step{step}")

    # -------------------------------------------------------------- write

    def _put(self, d: str, fname: str, data: bytes) -> None:
        """Atomic write: tmp in the same directory, then rename."""
        tmp = os.path.join(d, f".{fname}.tmp")
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, os.path.join(d, fname))

    def _link_from_base(self, base_dir: str, d: str, fname: str) -> bool:
        """Hard-link ``fname`` from the base step dir (atomically, via a tmp
        link + rename so a crashed link never leaves a partial name)."""
        src = os.path.join(base_dir, fname)
        if not os.path.exists(src):
            return False
        tmp = os.path.join(d, f".{fname}.lnk")
        try:
            if os.path.exists(tmp):
                os.remove(tmp)
            os.link(src, tmp)
            os.replace(tmp, os.path.join(d, fname))
            return True
        except OSError:
            return False

    def save_shard(self, job: str, region: str, step: int, shard: str,
                   arrays=None, meta: dict | None = None,
                   base_step: int | None = None) -> str:
        """Write one shard of checkpoint ``step``.

        With ``base_step`` (the last *committed* step, per the CR CRD), the
        write is incremental: the shard's content digest is compared to the
        base step's recorded digest and an unchanged payload is hard-linked
        from the base directory instead of rewritten — dirty-shard diffing,
        so a steady-state checkpoint writes only the shards that changed.
        """
        d = self._dir(job, region, step)
        os.makedirs(d, exist_ok=True)
        base_dir = (self._dir(job, region, base_step)
                    if base_step is not None and base_step >= 0
                    and base_step != step else None)
        if arrays is not None:
            flat = _flatten(arrays)
            digest = _digest(flat)
            linked = False
            if base_dir is not None \
                    and self._read_digest(base_dir, shard) == digest:
                linked = (self._link_from_base(base_dir, d, f"{shard}.npz")
                          and self._link_from_base(base_dir, d,
                                                   f"{shard}.npz.sha256"))
            if not linked:
                tmp = os.path.join(d, f".{shard}.npz.tmp")
                with open(tmp, "wb") as f:
                    np.savez(f, **flat)
                os.replace(tmp, os.path.join(d, f"{shard}.npz"))
                self._put(d, f"{shard}.npz.sha256", digest.encode())
        if meta is not None:
            blob = json.dumps(meta, sort_keys=True).encode()
            linked = False
            if base_dir is not None \
                    and self._read_bytes(base_dir, f"{shard}.json") == blob:
                linked = self._link_from_base(base_dir, d, f"{shard}.json")
            if not linked:
                self._put(d, f"{shard}.json", blob)
        return d

    @staticmethod
    def _read_digest(d: str, shard: str) -> str | None:
        path = os.path.join(d, f"{shard}.npz.sha256")
        try:
            with open(path, "rb") as f:
                return f.read().decode()
        except OSError:
            return None

    @staticmethod
    def _read_bytes(d: str, fname: str) -> bytes | None:
        try:
            with open(os.path.join(d, fname), "rb") as f:
                return f.read()
        except OSError:
            return None

    # --------------------------------------------------------------- read

    def load_shard(self, job: str, region: str, step: int, shard: str,
                   like=None):
        """Returns (arrays-or-unflattened, meta).  With ``like`` (a pytree),
        tensors are unflattened into its structure."""
        d = self._dir(job, region, step)
        arrays = None
        npz_path = os.path.join(d, f"{shard}.npz")
        if os.path.exists(npz_path):
            with np.load(npz_path) as z:
                flat = {k: z[k] for k in z.files}
            if like is not None:
                leaves = []
                for path, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
                    key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                   for p in path)
                    leaves.append(flat[key].astype(leaf.dtype).reshape(leaf.shape))
                arrays = jax.tree_util.tree_unflatten(
                    jax.tree_util.tree_structure(like), leaves)
            else:
                arrays = flat
        meta = None
        json_path = os.path.join(d, f"{shard}.json")
        if os.path.exists(json_path):
            with open(json_path) as f:
                meta = json.load(f)
        return arrays, meta

    def load_shard_at_or_before(self, job: str, region: str, step: int,
                                shard: str, like=None):
        """Load ``shard`` at ``step``, falling back to the newest older step
        that has it (a warm standby restored mid-commit, or a shard whose
        writer missed a barrier).  Returns ``(found_step, arrays, meta)``;
        ``(None, None, None)`` when no step at or below ``step`` has it."""
        for s in sorted((x for x in self.steps(job, region) if x <= step),
                        reverse=True):
            if self.has_shard(job, region, s, shard):
                arrays, meta = self.load_shard(job, region, s, shard,
                                               like=like)
                return s, arrays, meta
        return None, None, None

    def has_shard(self, job: str, region: str, step: int, shard: str) -> bool:
        d = self._dir(job, region, step)
        return (os.path.exists(os.path.join(d, f"{shard}.npz"))
                or os.path.exists(os.path.join(d, f"{shard}.json")))

    def steps(self, job: str, region: str) -> list:
        """Step ids present on disk for one region, ascending."""
        base = os.path.join(self.root, job, region)
        if not os.path.isdir(base):
            return []
        out = []
        for name in os.listdir(base):
            if name.startswith("step"):
                try:
                    out.append(int(name[4:]))
                except ValueError:
                    continue
        return sorted(out)

    # ------------------------------------------------------------- commit

    def mark_committing(self, job: str, region: str, step: int) -> None:
        """Stamp the commit-in-flight marker.  Called BEFORE the CRD status
        write: a concurrent sweep must never delete the step the CRD is
        mid-commit on."""
        d = self._dir(job, region, step)
        os.makedirs(d, exist_ok=True)
        self._put(d, COMMITTING_MARKER, b"")

    def clear_committing(self, job: str, region: str, step: int) -> None:
        """Drop the marker once the CRD write landed (idempotent)."""
        try:
            os.remove(os.path.join(self._dir(job, region, step),
                                   COMMITTING_MARKER))
        except OSError:
            pass

    def committing(self, job: str, region: str, step: int) -> bool:
        return os.path.exists(os.path.join(self._dir(job, region, step),
                                           COMMITTING_MARKER))

    def sweep(self, job: str, region: str, committed: int) -> int:
        """Delete strictly-older uncommitted step dirs.

        Only steps *below* ``committed`` are garbage — a newer step may be a
        checkpoint in flight — and a step carrying the ``.committing``
        marker is skipped outright even if older (its CRD write may still
        be racing this sweep).  Run from the failover conductor on commit
        events, not ad hoc from the commit path."""
        base = os.path.join(self.root, job, region)
        removed = 0
        if not os.path.isdir(base):
            return 0
        for name in os.listdir(base):
            if not name.startswith("step"):
                continue
            try:
                step = int(name[4:])
            except ValueError:
                continue
            if step >= committed:
                continue
            if os.path.exists(os.path.join(base, name, COMMITTING_MARKER)):
                continue
            shutil.rmtree(os.path.join(base, name), ignore_errors=True)
            removed += 1
        return removed
