"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (MHA: kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284].
The EnCodec/conditioning frontend is a stub per the assignment: ``input_specs``
provides precomputed conditioning frame embeddings (T5-dim 1024) which the
backbone projects and prepends to the token sequence (in lieu of
cross-attention; backbone-only scope — see DESIGN.md §4).
Non-gated 4x GELU FFN (d_ff = 4 * d_model), LayerNorm-free rms variant kept
consistent with the unified backbone.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    gated_mlp=False,
    rope_theta=10000.0,
    frontend="audio",
    frontend_dim=1024,
    frontend_len=64,
)
