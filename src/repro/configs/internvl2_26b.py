"""internvl2-26b [vlm] — InternViT + InternLM2-20B backbone [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT-6B
vision frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings (hidden 3200) which the MLP projector maps into
256 prefix positions of the LM sequence.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_dim=3200,
    frontend_len=256,
)
