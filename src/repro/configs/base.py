"""Architecture & shape configuration schema.

Every assigned architecture is a declarative ``ArchConfig``; the unified
model in ``repro.models.lm`` interprets it.  Configs are *data*, consistent
with the paper's principle that topology should be computed from a small
declarative spec rather than stored ("don't store what you can compute").
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoECfg:
    num_experts: int  # routed experts
    num_shared: int  # always-on shared experts
    top_k: int
    d_expert: int  # per-expert FFN width (fine-grained)
    capacity_factor: float = 1.25
    group_size: int = 512  # dispatch group size (tokens)
    shared_gate: bool = False  # qwen2-moe gates the shared expert output
    aux_loss_weight: float = 0.01
    impl: str = "einsum"  # "einsum" (GShard dense dispatch) | "sort" (argsort dispatch)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    act: str = "silu"  # silu | gelu
    gated_mlp: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0  # gemma-style final-logit soft capping (0 = off)
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)
    # Block pattern: repeating tuple of block kinds over the layer stack.
    # Kinds: "attn" (global causal), "local" (windowed causal),
    #        "rglru" (Griffin recurrent), "mlstm", "slstm" (xLSTM).
    block_pattern: tuple = ("attn",)
    window: int = 0  # local-attention window (tokens)
    d_rnn: int = 0  # RG-LRU recurrence width
    conv_width: int = 4  # temporal conv width for rglru/mlstm blocks
    moe: Optional[MoECfg] = None
    first_dense: int = 0  # first N layers use a dense MLP even in MoE archs
    first_dense_ff: int = 0  # width of that dense MLP (0 => d_ff)
    # Modality frontend stub (assignment: backbone only, embeddings precomputed)
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_dim: int = 0  # dim of precomputed frontend embeddings
    frontend_len: int = 0  # number of prefix positions provided by the frontend

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ------------------------------------------------------------ properties

    @property
    def q_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the vocab dim shards
        over the tensor axis (Megatron-style padding; padded logit columns
        are masked to -inf, so the model function is unchanged)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def layer_kinds(self) -> tuple:
        """Per-layer block kind, expanded from the repeating pattern."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def sub_quadratic(self) -> bool:
        """True iff no layer needs a full-sequence KV cache (long_500k eligible)."""
        return all(k != "attn" for k in self.layer_kinds)

    @property
    def has_attention(self) -> bool:
        return any(k in ("attn", "local") for k in self.layer_kinds)

    def param_count(self) -> int:
        """Total parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: shared + top_k experts only)."""
        return _count_params(self, active_only=True)

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    total += d  # final norm
    if cfg.frontend:
        total += cfg.frontend_dim * d
    for i, kind in enumerate(cfg.layer_kinds):
        total += 2 * d  # two block norms
        if kind in ("attn", "local"):
            total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if cfg.qkv_bias:
                total += h * hd + 2 * kv * hd
            if cfg.qk_norm:
                total += 2 * hd
        elif kind == "rglru":
            dr = cfg.d_rnn or d
            total += 2 * d * dr  # x and gate projections
            total += cfg.conv_width * dr  # temporal conv
            total += 3 * dr  # lambda, input-gate, rec-gate params (diagonal)
            total += dr * d  # out projection
        elif kind == "mlstm":
            di = 2 * d  # up-projection factor 2
            total += d * 2 * di  # up proj (x and gate)
            total += cfg.conv_width * di
            total += 3 * di * di // max(cfg.num_heads, 1) * cfg.num_heads  # q,k,v per head
            total += 3 * di  # i,f,o gate projections (per-channel from di)
            total += di * d  # down proj
        elif kind == "slstm":
            # 4 gates, each with input + recurrent (block-diag per head) weights
            total += 4 * d * d + 4 * d * (d // max(cfg.num_heads, 1))
            total += int(d * 4 / 3 * d * 2)  # post-FFN (proj factor 4/3, gated)
        # MLP / MoE
        if kind in ("attn", "local", "rglru"):
            is_moe = cfg.moe is not None and i >= cfg.first_dense
            if is_moe and kind != "rglru":
                m = cfg.moe
                routed = m.num_experts * 3 * d * m.d_expert
                shared = m.num_shared * 3 * d * m.d_expert
                router = d * m.num_experts
                if active_only:
                    routed = m.top_k * 3 * d * m.d_expert
                total += routed + shared + router
                if m.shared_gate:
                    total += d
            elif kind != "rglru" or cfg.d_ff > 0:
                ff = cfg.first_dense_ff if (cfg.moe is not None and i < cfg.first_dense and cfg.first_dense_ff) else cfg.d_ff
                if ff > 0:
                    mult = 3 if cfg.gated_mlp else 2
                    total += mult * d * ff
    return total


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not.

    long_500k needs sub-quadratic sequence handling: only archs whose
    attention footprint is bounded (pure SSM, or hybrid with *local*
    attention only) qualify.  Full-attention archs skip it (see DESIGN.md
    §Arch-applicability).
    """
    if shape.name == "long_500k":
        full_attn = any(k == "attn" for k in cfg.layer_kinds)
        if full_attn:
            return False, "full quadratic attention cannot serve a 524k-token context"
    return True, ""
