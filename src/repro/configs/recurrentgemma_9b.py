"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Pattern: two
RG-LRU recurrent blocks then one local-attention block (window 2048).
GeGLU MLP after every temporal-mixing block, head_dim=256, d_rnn=4096,
temporal conv width 4. Sub-quadratic: eligible for long_500k.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    d_rnn=4096,
    conv_width=4,
)
