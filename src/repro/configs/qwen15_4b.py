"""qwen1.5-4b [dense] — 40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.

QKV bias on (Qwen1.5 family trait) [hf:Qwen/Qwen1.5-0.5B]. SwiGLU MLP,
RMSNorm, RoPE.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
)
