"""Config registry: ``get_config(arch_id)`` resolves any assigned arch.

Also provides ``reduced_config`` (small same-family config for CPU smoke
tests) and the shape registry.
"""

from __future__ import annotations

from .base import ArchConfig, MoECfg, ShapeCfg, SHAPES, shape_applicable
from . import (
    deepseek_moe_16b,
    gemma_2b,
    internvl2_26b,
    musicgen_large,
    qwen15_4b,
    qwen2_moe_a27b,
    qwen3_14b,
    recurrentgemma_9b,
    xlstm_125m,
    yi_6b,
)

_REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        musicgen_large,
        qwen15_4b,
        qwen3_14b,
        yi_6b,
        gemma_2b,
        internvl2_26b,
        recurrentgemma_9b,
        deepseek_moe_16b,
        qwen2_moe_a27b,
        xlstm_125m,
    )
}

ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {', '.join(ARCH_IDS)}")
    return _REGISTRY[name]


def reduced_config(name: str) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests: same block pattern,
    same attention/MoE/recurrence structure, small dims."""
    full = get_config(name)
    kv = min(full.num_kv_heads, 2) if full.num_kv_heads < full.num_heads else 4
    moe = None
    if full.moe is not None:
        moe = MoECfg(
            num_experts=8,
            num_shared=min(full.moe.num_shared, 2),
            top_k=min(full.moe.top_k, 2),
            d_expert=64,
            capacity_factor=full.moe.capacity_factor,
            group_size=64,
            shared_gate=full.moe.shared_gate,
            impl=full.moe.impl,
        )
    n_layers = 2 * len(full.block_pattern)
    return full.with_(
        name=full.name + "-smoke",
        num_layers=n_layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=0 if full.d_ff == 0 else 256,
        vocab_size=512,
        window=min(full.window, 64) if full.window else 0,
        d_rnn=128 if full.d_rnn else 0,
        moe=moe,
        first_dense=min(full.first_dense, 1),
        first_dense_ff=256 if full.first_dense_ff else 0,
        frontend=full.frontend,
        frontend_dim=64 if full.frontend else 0,
        frontend_len=8 if full.frontend else 0,
    )


__all__ = [
    "ARCH_IDS",
    "ArchConfig",
    "MoECfg",
    "SHAPES",
    "ShapeCfg",
    "get_config",
    "reduced_config",
    "shape_applicable",
]
