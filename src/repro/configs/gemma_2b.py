"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU MLP, head_dim=256 (8*256=2048), MQA, tied embeddings, embeddings scaled
by sqrt(d_model) [arXiv:2403.08295].
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10000.0,
)
