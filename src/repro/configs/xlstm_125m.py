"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H vocab=50304, d_ff=0 (blocks carry their own expansion:
mLSTM up-projection factor 2, sLSTM post-FFN factor 4/3). Pattern: three
mLSTM blocks then one sLSTM block (xLSTM[3:1]-style). Sub-quadratic (matrix /
scalar memory states only): eligible for long_500k.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    act="gelu",
    gated_mlp=False,
    rope_theta=10000.0,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    conv_width=4,
)
