"""The paper's own test application (§8): source → n-way parallel region of
operator pipelines → sink.  This is the "application archive" used by the
platform benchmarks (job life cycle, width change, PE failure recovery), not
an LM architecture.  Operators and PEs follow the paper's fusion model: each
operator fuses into its own PE unless colocated.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class StreamsAppConfig:
    name: str = "paper-test-app"
    width: int = 4           # n-way parallel region
    pipeline_depth: int = 4  # operators per channel (paper: depth == width)
    pre_ops: int = 1         # operators before the parallel region
    post_ops: int = 1        # operators after the parallel region
    consistent_region: bool = False
    checkpoint_interval: int = 10  # tuples between checkpoints (when CR on)
    # adaptive emit batching (per-operator transport knobs; see PERuntime):
    # the controller sizes the output batch from observed load between the
    # min/max bounds, starting at emit_batch
    emit_batch: int = 64
    emit_batch_min: int = 1
    emit_batch_max: int = 512
    emit_adaptive: bool = True
    emit_linger: float = 0.002  # max seconds a buffered tuple may wait
    # graceful scale-down (job-level drain block; see crds.drain_config)
    drain_enabled: bool = True
    drain_timeout: float = 5.0   # seconds a retiring PE may drain
    drain_grace: float = 0.3     # input-silence window that counts as dry

    def drain_spec(self) -> dict:
        """The job-spec ``drain`` block this config corresponds to."""
        return {"enabled": self.drain_enabled, "timeout": self.drain_timeout,
                "grace": self.drain_grace}

    def emit_config(self) -> dict:
        """The per-operator transport config block (for channel/source ops)."""
        return {"emit_batch": self.emit_batch,
                "emit_batch_min": self.emit_batch_min,
                "emit_batch_max": self.emit_batch_max,
                "emit_adaptive": self.emit_adaptive,
                "emit_linger": self.emit_linger}

    @property
    def num_operators(self) -> int:
        return self.pre_ops + self.width * self.pipeline_depth + self.post_ops + 2  # + source/sink


CONFIG = StreamsAppConfig()


def square_app(width: int, consistent_region: bool = False) -> StreamsAppConfig:
    """The paper's scaling app: operator count grows with width**2."""
    return StreamsAppConfig(
        name=f"paper-test-app-w{width}",
        width=width,
        pipeline_depth=width,
        consistent_region=consistent_region,
    )
