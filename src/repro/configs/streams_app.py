"""The paper's own test application (§8): source → n-way parallel region of
operator pipelines → sink.  This is the "application archive" used by the
platform benchmarks (job life cycle, width change, PE failure recovery), not
an LM architecture.  Operators and PEs follow the paper's fusion model: each
operator fuses into its own PE unless colocated.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class StreamsAppConfig:
    name: str = "paper-test-app"
    width: int = 4           # n-way parallel region
    pipeline_depth: int = 4  # operators per channel (paper: depth == width)
    pre_ops: int = 1         # operators before the parallel region
    post_ops: int = 1        # operators after the parallel region
    consistent_region: bool = False
    checkpoint_interval: int = 10  # tuples between checkpoints (when CR on)

    @property
    def num_operators(self) -> int:
        return self.pre_ops + self.width * self.pipeline_depth + self.post_ops + 2  # + source/sink


CONFIG = StreamsAppConfig()


def square_app(width: int, consistent_region: bool = False) -> StreamsAppConfig:
    """The paper's scaling app: operator count grows with width**2."""
    return StreamsAppConfig(
        name=f"paper-test-app-w{width}",
        width=width,
        pipeline_depth=width,
        consistent_region=consistent_region,
    )
