"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936, per-expert d_ff=1408. The shared
expert output is gated by a sigmoid (shared_gate). QKV bias on (Qwen1.5
lineage). SwiGLU, RMSNorm, RoPE.
"""

from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    moe=MoECfg(
        num_experts=60,
        num_shared=4,
        top_k=4,
        d_expert=1408,
        capacity_factor=1.25,
        group_size=512,
        shared_gate=True,
    ),
)
