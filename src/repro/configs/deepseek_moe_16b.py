"""deepseek-moe-16b [moe] — fine-grained MoE with shared experts
[arXiv:2401.06066].

28L d_model=2048 16H (kv=16) vocab=102400. 2 shared + 64 routed experts,
top-6, per-expert d_ff=1408. First layer is a dense MLP (width 10944), as in
the paper. SwiGLU everywhere, RMSNorm, RoPE.
"""

from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    act="silu",
    gated_mlp=True,
    rope_theta=10000.0,
    moe=MoECfg(
        num_experts=64,
        num_shared=2,
        top_k=6,
        d_expert=1408,
        capacity_factor=1.25,
        group_size=512,
    ),
    first_dense=1,
    first_dense_ff=10944,
)
