"""Recurrent sequence-mixing blocks: RG-LRU (Griffin), mLSTM & sLSTM (xLSTM).

Each block provides:
- ``init_*``    — parameter construction,
- ``*_seq``     — full-sequence application (training / prefill),
- ``*_step``    — single-token application with carried state (decode).

Training-time forms are TPU-friendly: RG-LRU uses an associative scan,
mLSTM uses the chunkwise-parallel stabilized form (carry (C, n, m) across
chunks, quadratic only within a chunk), sLSTM is inherently sequential
(recurrent weights) and uses lax.scan.  The Pallas kernels in
``repro.kernels`` mirror rglru_seq and the mLSTM chunk recurrence.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layers import act_fn, dense_init, init_rmsnorm, rmsnorm

RGLRU_C = 8.0  # Griffin's fixed gate sharpness constant
SLSTM_REMAT_CELL = True  # perf lever (see EXPERIMENTS.md §Perf xlstm cell)
# Scan unroll was tried as a cheap way to let XLA merge the per-step
# recurrent-weight-gradient psums — refuted (no reassociation across the
# unrolled body); kept configurable for the record (§Perf).
SLSTM_SCAN_UNROLL = 1
# The decisive fix is the hand-written VJP below: the batch-contracted
# dR = Σ_t outer(h_{t-1}, dgate_t) is deferred to ONE einsum outside the
# backward loop, so the sharded-batch reduction costs a single psum instead
# of one per time step (measured: 97% of the cell's collective bytes).
SLSTM_CUSTOM_VJP = True


# ------------------------------------------------- sLSTM custom-VJP scan


def _gate_preacts(R, pre_stack, h_shift, num_heads):
    """a_g = pre_g + R_g · h_{t-1}, vectorized over time.

    R (4,H,dh,dh); pre_stack (4,S,B,d); h_shift (S,B,d) = [h0, h_0..h_{S-2}].
    Returns (4,S,B,d) f32.
    """
    S, B, d = h_shift.shape
    hh = h_shift.reshape(S, B, num_heads, d // num_heads)
    rec = jnp.einsum("sbhx,ghxy->gsbhy", hh, R)
    return pre_stack + rec.reshape(4, S, B, d)


def _slstm_forward_seqs(R, pre_stack, num_heads):
    """Sequential forward; returns (h_seq, c_seq, n_seq, m_seq), each (S,B,d),
    plus h0-prepended h_shift.  Minimal residuals: gates recompute from these.
    """
    _, S, B, d = pre_stack.shape
    dh = d // num_heads

    def step(state, pre_t):
        h, c, n, m = state
        hh = h.reshape(B, num_heads, dh)
        rec = jnp.einsum("bhx,ghxy->gbhy", hh, R).reshape(4, B, d)
        a = pre_t + rec  # (4,B,d): z,i,f,o
        z = jnp.tanh(a[0])
        o = jax.nn.sigmoid(a[3])
        lf = jax.nn.log_sigmoid(a[2])
        m_next = jnp.maximum(lf + m, a[1])
        i_sc = jnp.exp(a[1] - m_next)
        f_sc = jnp.exp(lf + m - m_next)
        c_next = f_sc * c + i_sc * z
        n_next = jnp.maximum(f_sc * n + i_sc, 1e-6)
        h_next = o * (c_next / n_next)
        return (h_next, c_next, n_next, m_next), (h_next, c_next, n_next, m_next)

    z0 = jnp.zeros((B, d), jnp.float32)
    state0 = (z0, z0, jnp.full((B, d), 1e-6, jnp.float32), z0)
    _, seqs = jax.lax.scan(step, state0, pre_stack.transpose(1, 0, 2, 3))
    return seqs, state0


def _slstm_scan_impl(R, pre_stack, num_heads):
    (h_seq, _c, _n, _m), _ = _slstm_forward_seqs(R, pre_stack, num_heads)
    return h_seq


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _slstm_scan(R, pre_stack, num_heads):
    """hs (S,B,d) = sLSTM over pre-activations with recurrent weights R."""
    return _slstm_scan_impl(R, pre_stack, num_heads)


def _slstm_scan_fwd(R, pre_stack, num_heads):
    seqs, state0 = _slstm_forward_seqs(R, pre_stack, num_heads)
    return seqs[0], (R, pre_stack, seqs, state0)


def _slstm_scan_bwd(num_heads, res, dhs):
    """Reverse pass with all weight-gradient reductions deferred.

    Residuals: only the four state sequences.  Gate quantities recompute
    *vectorized* over time; the sequential part is elementwise + constant-R
    matvecs (batch-local — no collective); dR is ONE einsum at the end, so
    the sharded-batch contraction costs a single psum for the whole scan
    (vs one per time step under plain autodiff)."""
    R, pre_stack, (h_seq, c_seq, n_seq, m_seq), state0 = res
    S, B, d = h_seq.shape
    dh = d // num_heads
    h0, c0, n0, m0 = state0

    def shift(seq, init):
        return jnp.concatenate([init[None], seq[:-1]], axis=0)

    h_prev = shift(h_seq, h0)
    c_prev = shift(c_seq, c0)
    n_prev = shift(n_seq, n0)
    m_prev = shift(m_seq, m0)

    # recompute gate quantities, vectorized over time (no loop, no psum-per-step)
    a = _gate_preacts(R, pre_stack, h_prev, num_heads)  # (4,S,B,d)
    z = jnp.tanh(a[0])
    o = jax.nn.sigmoid(a[3])
    lf = jax.nn.log_sigmoid(a[2])
    sg_naf = jax.nn.sigmoid(-a[2])  # d log_sigmoid(a_f)/d a_f
    i_sc = jnp.exp(a[1] - m_seq)
    f_sc = jnp.exp(lf + m_prev - m_seq)
    n_pre = f_sc * n_prev + i_sc
    uncl = (n_pre > 1e-6).astype(jnp.float32)
    mxl = ((lf + m_prev) >= a[1]).astype(jnp.float32)  # m-max takes left branch
    u = c_seq / n_seq

    def bwd_step(carry, xs):
        Dc_c, Dn_c, Dm_c, Dh_c = carry
        (dh_out, z_t, o_t, sgnaf_t, i_t, f_t, u_t, cp, npv, nt,
         uncl_t, mxl_t) = xs
        Dh = dh_out + Dh_c
        Da_o = Dh * u_t * o_t * (1.0 - o_t)
        Dc = Dc_c + Dh * o_t / nt
        Dn_tot = Dn_c - Dh * o_t * u_t / nt
        Dn_pre = Dn_tot * uncl_t  # n_t = max(n_pre, eps)
        Df = Dc * cp + Dn_pre * npv  # onto f_sc
        Di = Dc * z_t + Dn_pre  # onto i_sc
        Dz = Dc * i_t
        Dc_prev = Dc * f_t
        Dn_prev = Dn_pre * f_t
        # i_sc = exp(a_i - m_t); f_sc = exp(lf + m_prev - m_t)
        Da_i = Di * i_t
        Dm_t = Dm_c - Di * i_t - Df * f_t
        Dlf = Df * f_t
        Dm_prev = Df * f_t
        # m_t = max(lf + m_prev, a_i)
        Dlf = Dlf + Dm_t * mxl_t
        Dm_prev = Dm_prev + Dm_t * mxl_t
        Da_i = Da_i + Dm_t * (1.0 - mxl_t)
        Da_f = Dlf * sgnaf_t
        Da_z = Dz * (1.0 - z_t * z_t)
        Da = jnp.stack([Da_z, Da_i, Da_f, Da_o])  # (4,B,d)
        # h_{t-1} chain through the recurrent matvecs (R constant here)
        Da_h = Da.reshape(4, B, num_heads, dh)
        Dh_prev = jnp.einsum("gbhy,ghxy->bhx", Da_h, R).reshape(B, d)
        return (Dc_prev, Dn_prev, Dm_prev, Dh_prev), Da

    zero = jnp.zeros((B, d), jnp.float32)
    xs = (dhs, z, o, sg_naf, i_sc, f_sc, u, c_prev, n_prev, n_seq, uncl, mxl)
    _, Das = jax.lax.scan(bwd_step, (zero, zero, zero, zero), xs, reverse=True)
    # Das: (S,4,B,d).  Deferred weight grads: ONE batch+time contraction.
    Da_heads = Das.reshape(S, 4, B, num_heads, dh)
    hp_heads = h_prev.reshape(S, B, num_heads, dh)
    DR = jnp.einsum("sbhx,sgbhy->ghxy", hp_heads, Da_heads)
    Dpre = Das.transpose(1, 0, 2, 3)  # (4,S,B,d)
    return DR, Dpre


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


# -------------------------------------------------------------------- rg-lru


def init_rglru(key, d: int, d_rnn: int, conv_width: int) -> dict:
    ks = jax.random.split(key, 8)
    # Λ init so that a = exp(-c*softplus(Λ)) is spread in [0.9, 0.999] (Griffin §2.4)
    u = jax.random.uniform(ks[6], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))  # softplus^-1(-log(u)/c)
    return {
        "w_x": dense_init(ks[0], (d, d_rnn)),
        "w_g": dense_init(ks[1], (d, d_rnn)),
        "conv_w": dense_init(ks[2], (conv_width, d_rnn)),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_a": dense_init(ks[3], (d_rnn, d_rnn)),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": dense_init(ks[4], (d_rnn, d_rnn)),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": lam,
        "w_o": dense_init(ks[5], (d_rnn, d)),
    }


def causal_conv_seq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x (B,S,C), w (W,C)."""
    W = w.shape[0]
    out = x * w[W - 1].astype(x.dtype)
    for j in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[W - 1 - j].astype(x.dtype)
    return out + b.astype(x.dtype)


def causal_conv_step(x: jax.Array, state: jax.Array, w: jax.Array, b: jax.Array):
    """x (B,C); state (B,W-1,C) holds the previous W-1 inputs (oldest first)."""
    W = w.shape[0]
    window = jnp.concatenate([state, x[:, None]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w) + b
    new_state = window[:, 1:]
    return out.astype(x.dtype), new_state


def _rglru_gates(params, xr):
    """xr (..., d_rnn) post-conv input -> (log_a f32, b_input f32)."""
    x32 = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(x32 @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r  # (..., d_rnn), <= 0
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x32)
    return log_a, b


def rglru_seq(params: dict, x: jax.Array, return_state: bool = False):
    """Full RG-LRU sequence mix.  x (B,S,d) (already normed) -> (B,S,d)."""
    dt = x.dtype
    gate = act_fn("gelu")(x @ params["w_g"].astype(dt))
    xr_pre = x @ params["w_x"].astype(dt)
    xr = causal_conv_seq(xr_pre, params["conv_w"], params["conv_b"])
    log_a, b = _rglru_gates(params, xr)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al + ar, bl * jnp.exp(ar) + br

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    out = ((h.astype(dt) * gate) @ params["w_o"].astype(dt)).astype(dt)
    if return_state:
        state = {"h": h[:, -1].astype(jnp.float32),
                 "conv": _conv_tail(xr_pre, params["conv_w"].shape[0])}
        return out, state
    return out


def _conv_tail(x_pre: jax.Array, W: int) -> jax.Array:
    """Last W-1 pre-conv inputs (zero-padded at the front), oldest first."""
    B, S, C = x_pre.shape
    n = W - 1
    if S >= n:
        return x_pre[:, S - n:]
    pad = jnp.zeros((B, n - S, C), x_pre.dtype)
    return jnp.concatenate([pad, x_pre], axis=1)


def rglru_step(params: dict, x: jax.Array, state: dict):
    """One decode step.  x (B,d); state {h (B,dr) f32, conv (B,W-1,dr)}."""
    dt = x.dtype
    gate = act_fn("gelu")(x @ params["w_g"].astype(dt))
    xr = x @ params["w_x"].astype(dt)
    xr, conv_state = causal_conv_step(xr, state["conv"], params["conv_w"], params["conv_b"])
    log_a, b = _rglru_gates(params, xr)
    h = state["h"] * jnp.exp(log_a) + b
    out = ((h.astype(dt) * gate) @ params["w_o"].astype(dt)).astype(dt)
    return out, {"h": h, "conv": conv_state}


def rglru_init_state(batch: int, d_rnn: int, conv_width: int, dtype=jnp.bfloat16) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
    }


# --------------------------------------------------------------------- mlstm


def init_mlstm(key, d: int, num_heads: int, conv_width: int) -> dict:
    di = 2 * d  # up-projection factor 2
    dk = di // num_heads
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di)),  # (x_inner, z-gate)
        "conv_w": dense_init(ks[1], (conv_width, di)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[2], (di, num_heads, dk)),
        "wk": dense_init(ks[3], (di, num_heads, dk)),
        "wv": dense_init(ks[4], (di, num_heads, dk)),
        "w_i": dense_init(ks[5], (di, num_heads)),
        "b_i": jnp.full((num_heads,), -3.0, jnp.float32),
        "w_f": dense_init(ks[6], (di, num_heads)),
        "b_f": jnp.linspace(3.0, 6.0, num_heads).astype(jnp.float32),
        "gn": init_rmsnorm(di),
        "w_down": dense_init(ks[7], (di, d)),
    }


def _mlstm_qkvif(params, xc, x_inner, num_heads):
    """Project conv output / inner stream to per-head q,k,v and gate preacts."""
    dt = xc.dtype
    q = jnp.einsum("bsd,dhe->bshe", xc, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", xc, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x_inner, params["wv"].astype(dt))
    i_pre = jnp.einsum("bsd,dh->bsh", xc.astype(jnp.float32), params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bsd,dh->bsh", xc.astype(jnp.float32), params["w_f"]) + params["b_f"]
    return q, k, v, i_pre, f_pre


def mlstm_chunk_recurrence(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                           return_final: bool = False):
    """Chunkwise-parallel stabilized mLSTM recurrence (the ref the Pallas
    kernel mirrors).

    q,k,v: (B,S,H,dk) ; i_pre,f_pre: (B,S,H) preactivations.
    Returns h (B,S,H,dk) f32.
    """
    B, S, H, dk = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    scale = 1.0 / math.sqrt(dk)

    # (B,H,nc,c,dk) layouts
    qs = q.transpose(0, 2, 1, 3).reshape(B, H, nc, c, dk).astype(jnp.float32) * scale
    ks = k.transpose(0, 2, 1, 3).reshape(B, H, nc, c, dk).astype(jnp.float32)
    vs = v.transpose(0, 2, 1, 3).reshape(B, H, nc, c, dk).astype(jnp.float32)
    log_i = i_pre.transpose(0, 2, 1).reshape(B, H, nc, c)
    log_f = jax.nn.log_sigmoid(f_pre).transpose(0, 2, 1).reshape(B, H, nc, c)

    def body(carry, xs_t):
        C, n, m = carry  # (B,H,dk,dk), (B,H,dk), (B,H)
        qt, kt, vt, li, lf = xs_t  # (B,H,c,dk) ... (B,H,c)
        csum = jnp.cumsum(lf, axis=-1)  # b_i: decay from chunk start to i
        total = csum[..., -1:]  # (B,H,1)
        # intra-chunk log weights D[i,j] = csum_i - csum_j + li_j (j <= i)
        D = csum[..., :, None] - csum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tri, D, -jnp.inf)
        g = csum + m[..., None]  # inter contribution magnitude per position
        m_i = jnp.maximum(jnp.max(D, axis=-1), g)  # (B,H,c)
        w_intra = jnp.exp(D - m_i[..., None])
        S_qk = jnp.einsum("bhqd,bhkd->bhqk", qt, kt)
        W = S_qk * w_intra
        inter_scale = jnp.exp(g - m_i)  # (B,H,c)
        num = jnp.einsum("bhqk,bhkd->bhqd", W, vt) + inter_scale[..., None] * jnp.einsum(
            "bhqd,bhde->bhqe", qt, C)
        den = jnp.sum(W, axis=-1) + inter_scale * jnp.einsum("bhqd,bhd->bhq", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # carry update to the end of the chunk
        dec = total - csum + li  # (B,H,c): weight of k_j v_j at chunk end
        m_next = jnp.maximum(m + total[..., 0], jnp.max(dec, axis=-1))
        w_new = jnp.exp(dec - m_next[..., None])
        C_next = jnp.exp(m + total[..., 0] - m_next)[..., None, None] * C + jnp.einsum(
            "bhk,bhkd,bhke->bhde", w_new, kt, vt)
        n_next = jnp.exp(m + total[..., 0] - m_next)[..., None] * n + jnp.einsum(
            "bhk,bhkd->bhd", w_new, kt)
        return (C_next, n_next, m_next), h

    C0 = jnp.zeros((B, H, dk, dk), jnp.float32)
    n0 = jnp.zeros((B, H, dk), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = (qs.transpose(2, 0, 1, 3, 4), ks.transpose(2, 0, 1, 3, 4),
          vs.transpose(2, 0, 1, 3, 4), log_i.transpose(2, 0, 1, 3),
          log_f.transpose(2, 0, 1, 3))
    final, hs = jax.lax.scan(body, (C0, n0, m0), xs)
    # hs (nc,B,H,c,dk) -> (B,S,H,dk)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dk)
    if return_final:
        return h, final
    return h


def mlstm_seq(params: dict, x: jax.Array, num_heads: int, *, chunk: int = 128,
              recurrence=None, return_state: bool = False):
    """Full mLSTM block mix.  x (B,S,d) normed -> (B,S,d).

    ``recurrence`` may override the chunk recurrence with a Pallas kernel.
    """
    dt = x.dtype
    di = 2 * x.shape[-1]
    up = x @ params["w_up"].astype(dt)
    x_inner, z = up[..., :di], up[..., di:]
    xc = causal_conv_seq(x_inner, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, xc, x_inner, num_heads)
    if return_state:
        h, (C, n, m) = mlstm_chunk_recurrence(q, k, v, i_pre, f_pre, chunk=chunk,
                                              return_final=True)
    else:
        rec_fn = recurrence or mlstm_chunk_recurrence
        h = rec_fn(q, k, v, i_pre, f_pre, chunk=chunk)  # (B,S,H,dk) f32
    B, S = x.shape[:2]
    h = h.reshape(B, S, di)
    h = rmsnorm(h.astype(dt), params["gn"]["scale"])
    h = h * jax.nn.silu(z)
    out = (h @ params["w_down"].astype(dt)).astype(dt)
    if return_state:
        state = {"C": C, "n": n, "m": m,
                 "conv": _conv_tail(x_inner, params["conv_w"].shape[0])}
        return out, state
    return out


def mlstm_step(params: dict, x: jax.Array, state: dict, num_heads: int):
    """One decode step.  x (B,d); state {C (B,H,dk,dk), n, m, conv}."""
    dt = x.dtype
    di = 2 * x.shape[-1]
    up = x @ params["w_up"].astype(dt)
    x_inner, z = up[..., :di], up[..., di:]
    xc, conv_state = causal_conv_step(x_inner, state["conv"], params["conv_w"],
                                      params["conv_b"])
    xc = jax.nn.silu(xc)
    q = jnp.einsum("bd,dhe->bhe", xc, params["wq"].astype(dt)).astype(jnp.float32)
    k = jnp.einsum("bd,dhe->bhe", xc, params["wk"].astype(dt)).astype(jnp.float32)
    v = jnp.einsum("bd,dhe->bhe", x_inner, params["wv"].astype(dt)).astype(jnp.float32)
    i_pre = jnp.einsum("bd,dh->bh", xc.astype(jnp.float32), params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bd,dh->bh", xc.astype(jnp.float32), params["w_f"]) + params["b_f"]
    log_f = jax.nn.log_sigmoid(f_pre)
    dk = q.shape[-1]
    q = q / math.sqrt(dk)
    C, n, m = state["C"], state["n"], state["m"]
    m_next = jnp.maximum(log_f + m, i_pre)
    f_sc = jnp.exp(log_f + m - m_next)
    i_sc = jnp.exp(i_pre - m_next)
    C_next = f_sc[..., None, None] * C + i_sc[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    n_next = f_sc[..., None] * n + i_sc[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_next)
    den = jnp.einsum("bhd,bhd->bh", q, n_next)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_next))[..., None]
    B = x.shape[0]
    h = h.reshape(B, di)
    h = rmsnorm(h.astype(dt), params["gn"]["scale"])
    h = h * jax.nn.silu(z)
    out = (h @ params["w_down"].astype(dt)).astype(dt)
    return out, {"C": C_next, "n": n_next, "m": m_next, "conv": conv_state}


def mlstm_init_state(batch: int, d: int, num_heads: int, conv_width: int,
                     dtype=jnp.bfloat16) -> dict:
    di = 2 * d
    dk = di // num_heads
    return {
        "C": jnp.zeros((batch, num_heads, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dk), jnp.float32),
        "m": jnp.zeros((batch, num_heads), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, di), dtype),
    }


# --------------------------------------------------------------------- slstm


def init_slstm(key, d: int, num_heads: int) -> dict:
    dh = d // num_heads
    ks = jax.random.split(key, 12)
    p = {}
    for gi, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = dense_init(ks[2 * gi], (d, d))
        p[f"r_{gate}"] = dense_init(ks[2 * gi + 1], (num_heads, dh, dh))
        p[f"b_{gate}"] = (jnp.linspace(3.0, 6.0, d).astype(jnp.float32)
                          if gate == "f" else jnp.zeros((d,), jnp.float32))
    p["gn"] = init_rmsnorm(d)
    p["w_o_proj"] = dense_init(ks[8], (d, d))
    d_ff = max(int(round(d * 4 / 3 / 64) * 64), 64)
    p["ffn"] = {
        "norm": init_rmsnorm(d),
        "w_gate": dense_init(ks[9], (d, d_ff)),
        "w_up": dense_init(ks[10], (d, d_ff)),
        "w_down": dense_init(ks[11], (d_ff, d)),
    }
    return p


def _slstm_cell(params, x_pre: dict, state: dict, num_heads: int):
    """One sLSTM step from precomputed input projections.

    x_pre: dict gate -> (B,d) f32 input contributions (W_g x + b_g).
    state: {h (B,d), c (B,d), n (B,d), m (B,d)} f32.
    """
    B, d = x_pre["z"].shape
    dh = d // num_heads
    h_heads = state["h"].reshape(B, num_heads, dh)

    def rec(gate):
        r = jnp.einsum("bhx,hxy->bhy", h_heads, params[f"r_{gate}"]).reshape(B, d)
        return x_pre[gate] + r

    z = jnp.tanh(rec("z"))
    i_pre = rec("i")
    f_pre = rec("f")
    o = jax.nn.sigmoid(rec("o"))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_next = jnp.maximum(log_f + state["m"], i_pre)
    i_sc = jnp.exp(i_pre - m_next)
    f_sc = jnp.exp(log_f + state["m"] - m_next)
    c_next = f_sc * state["c"] + i_sc * z
    n_next = jnp.maximum(f_sc * state["n"] + i_sc, 1e-6)
    h_next = o * (c_next / n_next)
    return {"h": h_next, "c": c_next, "n": n_next, "m": m_next}


def slstm_seq(params: dict, x: jax.Array, num_heads: int,
              return_state: bool = False):
    """Full sLSTM block (cell + GN + out proj + gated FFN residual inside)."""
    dt = x.dtype
    B, S, d = x.shape
    x32 = x.astype(jnp.float32)
    pre = {g: x32 @ params[f"w_{g}"] + params[f"b_{g}"] for g in ("z", "i", "f", "o")}
    state0 = slstm_init_state(B, d)

    if SLSTM_CUSTOM_VJP and not return_state:
        R = jnp.stack([params[f"r_{g}"] for g in ("z", "i", "f", "o")])
        pre_stack = jnp.stack([pre[g].transpose(1, 0, 2)
                               for g in ("z", "i", "f", "o")])  # (4,S,B,d)
        hs = _slstm_scan(R, pre_stack, num_heads)  # (S,B,d)
        final = None
    else:
        # checkpoint the cell: the scan's backward otherwise stashes every
        # per-step gate intermediate (~12 full (S,B,d) f32 buffers/layer);
        # recompute is nearly free.  SLSTM_REMAT_CELL exists so §Perf can
        # measure the before/after.
        def body(state, xs):
            state = _slstm_cell(params, {g: xs[gi] for gi, g in
                                         enumerate(("z", "i", "f", "o"))},
                                state, num_heads)
            return state, state["h"]

        if SLSTM_REMAT_CELL:
            body = jax.checkpoint(body)

        xs = tuple(pre[g].transpose(1, 0, 2) for g in ("z", "i", "f", "o"))
        final, hs = jax.lax.scan(body, state0, xs,
                                 unroll=min(SLSTM_SCAN_UNROLL, S))
    h = hs.transpose(1, 0, 2).astype(dt)  # (B,S,d)
    h = rmsnorm(h, params["gn"]["scale"])
    out = (h @ params["w_o_proj"].astype(dt)).astype(dt)
    # gated FFN sub-layer (xLSTM post-up projection, pf 4/3)
    y = rmsnorm(out + x, params["ffn"]["norm"]["scale"])
    g = jax.nn.gelu((y @ params["ffn"]["w_gate"].astype(dt)).astype(jnp.float32))
    u = (y @ params["ffn"]["w_up"].astype(dt)).astype(jnp.float32)
    ff = ((g * u).astype(dt) @ params["ffn"]["w_down"].astype(dt)).astype(dt)
    result = out + ff  # caller adds the block-input residual
    if return_state:
        return result, final
    return result


def slstm_step(params: dict, x: jax.Array, state: dict, num_heads: int):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    pre = {g: x32 @ params[f"w_{g}"] + params[f"b_{g}"] for g in ("z", "i", "f", "o")}
    new_state = _slstm_cell(params, pre, state, num_heads)
    h = rmsnorm(new_state["h"].astype(dt), params["gn"]["scale"])
    out = (h @ params["w_o_proj"].astype(dt)).astype(dt)
    y = rmsnorm(out + x, params["ffn"]["norm"]["scale"])
    g = jax.nn.gelu((y @ params["ffn"]["w_gate"].astype(dt)).astype(jnp.float32))
    u = (y @ params["ffn"]["w_up"].astype(dt)).astype(jnp.float32)
    ff = ((g * u).astype(dt) @ params["ffn"]["w_down"].astype(dt)).astype(dt)
    return out + ff, new_state


def slstm_init_state(batch: int, d: int) -> dict:
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": jnp.full((batch, d), 1e-6, jnp.float32), "m": z}
