"""Mixture-of-Experts layer: shared + fine-grained routed experts.

Covers deepseek-moe-16b (2 shared + 64 routed, top-6) and qwen2-moe-a2.7b
(4 shared + 60 routed, top-4, sigmoid-gated shared expert).

Two dispatch implementations, selectable via ``MoECfg.impl``:

- ``einsum``: GShard-style dense dispatch/combine tensors (capacity-based,
  one-hot einsums).  SPMD-friendly — the partitioner turns the group/expert
  einsums into clean all-to-alls — but pays ~2*T*E*C*d extra dispatch FLOPs
  (the known GShard overhead, significant for fine-grained experts).
- ``sort``: argsort-based dispatch (scatter into an (E, C, d) buffer, grouped
  GEMM, gather back).  Eliminates the dispatch-einsum FLOPs; used in the
  §Perf hillclimb to attack the compute roofline term of the MoE cells.

Both are capacity-based with identical drop semantics, so they can be
cross-checked against each other (see tests/test_moe.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import MoECfg
from ..sharding.ctx import shard
from .layers import act_fn, dense_init, mlp_apply


def init_moe(key, d: int, m: MoECfg) -> dict:
    keys = jax.random.split(key, 8)
    de = m.d_expert
    p = {
        "router": dense_init(keys[0], (d, m.num_experts)),
        # routed experts (E, d, de): gated MLPs
        "w_gate": dense_init(keys[1], (m.num_experts, d, de)),
        "w_up": dense_init(keys[2], (m.num_experts, d, de)),
        "w_down": dense_init(keys[3], (m.num_experts, de, d)),
    }
    if m.num_shared:
        ds = m.num_shared * de
        p["shared"] = {
            "w_gate": dense_init(keys[4], (d, ds)),
            "w_up": dense_init(keys[5], (d, ds)),
            "w_down": dense_init(keys[6], (ds, d)),
        }
        if m.shared_gate:
            p["shared_gate"] = dense_init(keys[7], (d, 1))
    return p


def _capacity(m: MoECfg, g: int) -> int:
    return max(4, int(math.ceil(g * m.top_k * m.capacity_factor / m.num_experts)))


def _route(params, xg, m: MoECfg):
    """xg (n, g, d) -> (gate_vals (n,g,k), idx (n,g,k), probs (n,g,E))."""
    logits = jnp.einsum("ngd,de->nge", xg, params["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)
    return gate_vals, idx, probs


def _aux_loss(probs, idx, m: MoECfg) -> jax.Array:
    """Load-balance loss: E * sum_e f_e * P_e (Switch/GShard form)."""
    E = m.num_experts
    top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    f = jnp.mean(top1, axis=(0, 1))
    P = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(f * P)


def _experts_gemm(params, xe, act: str):
    """xe (n, E, C, d) -> (n, E, C, d) through per-expert gated MLPs."""
    dt = xe.dtype
    g = jnp.einsum("necd,edf->necf", xe, params["w_gate"].astype(dt),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("necd,edf->necf", xe, params["w_up"].astype(dt),
                   preferred_element_type=jnp.float32)
    h = (act_fn(act)(g) * u).astype(dt)
    return jnp.einsum("necf,efd->necd", h, params["w_down"].astype(dt),
                      preferred_element_type=jnp.float32).astype(dt)


def _moe_einsum(params, xg, m: MoECfg, act: str):
    """GShard dense-dispatch path.  xg (n, g, d)."""
    n, g, d = xg.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(m, g)
    gate_vals, idx, probs = _route(params, xg, m)

    mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (n, g, k, E)
    # GShard ordering: all tokens' choice 0, then choice 1, ... — transpose k
    # in front of g before the running count.
    mask_kg = mask.transpose(0, 2, 1, 3).reshape(n, k * g, E)
    pos = jnp.cumsum(mask_kg, axis=1) * mask_kg - mask_kg  # 0-based slot index
    keep = (pos < C) * mask_kg  # (n, k*g, E)
    pos = pos.reshape(n, k, g, E).transpose(0, 2, 1, 3)  # (n, g, k, E)
    keep = keep.reshape(n, k, g, E).transpose(0, 2, 1, 3)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C,
                            dtype=jnp.float32) * keep[..., None]  # (n,g,k,E,C)
    combine = jnp.sum(gate_vals[..., None, None] * pos_oh, axis=2)  # (n, g, E, C)
    combine = shard(combine, ("dp", None, "expert", None))
    dispatch = (combine > 0).astype(xg.dtype)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg,
                    preferred_element_type=jnp.float32).astype(xg.dtype)
    xe = shard(xe, ("dp", "expert", None, None))
    ye = _experts_gemm(params, xe, act)
    # combine contracts over the EP-sharded expert dim -> cross-shard psum;
    # bf16 output halves its wire bytes
    out = jnp.einsum("ngec,necd->ngd", combine.astype(xg.dtype), ye,
                     preferred_element_type=xg.dtype).astype(xg.dtype)
    return out, _aux_loss(probs, idx, m)


def _moe_sort(params, xg, m: MoECfg, act: str):
    """Argsort dispatch path: no dense dispatch/combine einsums.

    Same capacity & drop semantics as the einsum path, but slot assignment is
    computed with sort/segment arithmetic and data movement is scatter/gather
    instead of one-hot matmuls.  Applied per group for identical capacity
    behaviour (vmap over groups).
    """
    E, k = m.num_experts, m.top_k
    n, g, d = xg.shape
    C = _capacity(m, g)
    gate_vals, idx, probs = _route(params, xg, m)

    def one_group(x, gv, ix):
        # x (g, d); gv/ix (g, k)
        a = g * k
        # GShard ordering: choice-major (all choice-0 assignments first), so
        # capacity drops prefer lower-rank choices — identical semantics to
        # the einsum path.  Sequence index j = choice * g + token.
        tok_of = jnp.tile(jnp.arange(g), k)
        choice_of = jnp.repeat(jnp.arange(k), g)
        e_seq = ix[tok_of, choice_of]  # (a,)
        gate_seq = gv[tok_of, choice_of]
        order = jnp.argsort(e_seq, stable=True)
        e_sorted = e_seq[order]
        counts = jnp.bincount(e_seq, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(a) - starts[e_sorted]
        keep = pos < C
        # dropped assignments write out of bounds -> discarded by mode="drop"
        slot = jnp.where(keep, e_sorted * C + pos, E * C)
        tok_sorted = tok_of[order]
        buf = jnp.zeros((E * C, d), x.dtype)
        buf = buf.at[slot].set(x[tok_sorted], mode="drop")
        return buf.reshape(E, C, d), tok_sorted, gate_seq[order], keep, slot

    xs, toks, gates, keeps, slots = jax.vmap(one_group)(xg, gate_vals, idx)
    xs = shard(xs, ("dp", "expert", None, None))
    ye = _experts_gemm(params, xs, act)  # (n, E, C, d)

    def combine_group(y, tok_sorted, gate_sorted, keep, slot):
        vals = y.reshape(E * C, d).at[slot].get(mode="fill", fill_value=0.0)
        vals = vals * (gate_sorted * keep)[:, None]
        out = jnp.zeros((g, d), jnp.float32)
        return out.at[tok_sorted].add(vals.astype(jnp.float32))

    out = jax.vmap(combine_group)(ye, toks, gates, keeps, slots)
    return out.astype(xg.dtype), _aux_loss(probs, idx, m)


def moe_apply(params: dict, x: jax.Array, m: MoECfg, act: str):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    g = min(m.group_size, T)
    assert T % g == 0, (T, g)
    xg = x.reshape(T // g, g, d)
    xg = shard(xg, ("dp", None, None))
    if m.impl == "sort":
        out, aux = _moe_sort(params, xg, m, act)
    else:
        out, aux = _moe_einsum(params, xg, m, act)
    out = out.reshape(B, S, d)
    if "shared" in params:
        y = mlp_apply(params["shared"], x, act, gated=True)
        if "shared_gate" in params:
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,do->bso", x.astype(jnp.float32),
                           params["shared_gate"].astype(jnp.float32)))
            y = (y.astype(jnp.float32) * gate).astype(x.dtype)
        out = out + y
    return out, aux
