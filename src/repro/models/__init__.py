from .lm import (
    ModelOptions,
    abstract_params,
    decode_step,
    forward,
    forward_with_cache,
    init_cache,
    init_params,
    loss_fn,
    stack_plan,
)

__all__ = [
    "ModelOptions",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "stack_plan",
]
