"""Shared model layers: norms, RoPE, blockwise attention, MLPs.

Conventions
-----------
- Parameters are plain nested dicts of ``jnp.ndarray`` (fp32 master copies).
- Compute is bf16 with fp32 accumulation (``preferred_element_type``).
- Attention is *blockwise* (online-softmax over KV chunks) so the XLA path
  never materializes an S×S score matrix — the same memory shape the Pallas
  flash kernel targets on TPU.  ``repro.kernels`` provides the TPU kernels;
  these functions are the reference/XLA path used by the CPU dry-run.
- Head layout: flattened H everywhere in full-sequence attention (GQA KV
  heads are pre-expanded by the caller, kv head j -> q heads j*G..j*G+G-1);
  decode keeps the compact (KV, G) grouping since the cache stays compact.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..sharding.ctx import shard

DEFAULT_COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# --------------------------------------------------------------------- norms


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rmsnorm(d: int) -> dict:
    # Stored as deltas from 1.0 (gemma convention); init 0 == unit scale.
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------- rope


def rope_table(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) int32 -> (sin, cos) each (..., head_dim/2) float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., H, D); sin/cos (..., D/2) — broadcast over the head axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :].astype(jnp.float32)
    cos = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention


def _online_update(m, l, acc, scores, v_blk):
    """One online-softmax accumulation step.

    scores: (..., q, k) f32 (already masked); v_blk: (..., k, D) with batch
    dims broadcastable against the score batch dims.
    m, l: (..., q) f32; acc: (..., q, D) f32.
    """
    m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("...qk,...kd->...qd", p, v_blk.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def blockwise_causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    causal: bool = True,
    skip_masked_blocks: bool = False,
) -> jax.Array:
    """Online-softmax blockwise attention.  q (B,S,H,D); k,v (B,S,H,D)
    (GQA KV heads pre-expanded to H by the caller — flattened head layout
    shards cleanly over the tensor axis, unlike a (KV, G) factorization).

    The baseline computes every (q, kv) block pair and masks — the paper-
    faithful naive data plane (2x causal FLOP waste).
    ``skip_masked_blocks=True`` switches to ``tree_causal_attention`` which
    performs only the causal work (beyond-paper optimization, §Perf).
    """
    if skip_masked_blocks and causal:
        return tree_causal_attention(q, k, v, chunk=q_chunk)
    B, S, H, D = q.shape
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    nq, nk = S // q_chunk, S // kv_chunk
    scale = 1.0 / math.sqrt(D)

    # Pin batch/head sharding on scanned operands and carries: without these
    # the scan-cotangent accumulation in backward loses the batch sharding
    # and XLA all-gathers K/V to the *global* batch inside the loop
    # (measured: 62% of all collective bytes on qwen3 train_4k).
    blk_ax = (None, "batch", "heads", None, None)
    qs = shard(q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 3, 2, 4), blk_ax)
    ks = shard(k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 3, 2, 4), blk_ax)
    vs = shard(v.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 3, 2, 4), blk_ax)
    q_starts = jnp.arange(nq) * q_chunk
    k_starts = jnp.arange(nk) * kv_chunk
    carry_ax = ("batch", "heads", None)

    def q_body(_, xq):
        q_blk, q0 = xq  # (B,H,qc,D), scalar
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)

        # checkpoint the chunk body: backward recomputes scores from
        # (q_blk, k_blk) instead of stashing exp-scores for every chunk —
        # the flash-attention memory trade, applied to the XLA path
        @jax.checkpoint
        def kv_body(carry, xk):
            m, l, acc = carry
            k_blk, v_blk, k0 = xk
            k_blk = shard(k_blk, ("batch", "heads", None, None))
            v_blk = shard(v_blk, ("batch", "heads", None, None))
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qp = q0 + jnp.arange(q_chunk)
                kp = k0 + jnp.arange(kv_chunk)
                mask = qp[:, None] >= kp[None, :]
                s = jnp.where(mask, s, NEG_INF)
            m, l, acc = _online_update(m, l, acc, s, v_blk)
            m = shard(m, carry_ax)
            l = shard(l, carry_ax)
            acc = shard(acc, carry_ax + (None,))
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, k_starts))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, q_starts))
    # outs: (nq,B,H,qc,D) -> (B,S,H,D)
    return outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)


def tree_causal_attention(q, k, v, *, chunk: int = 512) -> jax.Array:
    """Binary-tree causal decomposition: exactly the causal FLOPs.

    Causal attention over S decomposes into masked diagonal blocks of size
    ``chunk`` plus log2(S/chunk) levels of *unmasked* block-dense cross
    attention (the top half of every span attends the bottom half).  Score
    FLOPs = S*chunk + S^2/2 vs ~S^2 for masked-blockwise — the beyond-paper
    compute-term optimization recorded in EXPERIMENTS.md §Perf.  Partial
    (m, l, acc) statistics from all levels merge via online softmax: exact.
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c

    ax = ("batch", None, None, "heads", None)
    qs = shard(q.reshape(B, nc, c, H, D), ax)
    ks = shard(k.reshape(B, nc, c, H, D), ax)
    vs = shard(v.reshape(B, nc, c, H, D), ax)

    # --- diagonal blocks (masked causal within each chunk)
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qs, ks,
                   preferred_element_type=jnp.float32) * scale
    dmask = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
    s = jnp.where(dmask, s, NEG_INF)
    m = jnp.full((B, nc, H, c), NEG_INF, jnp.float32)
    l = jnp.zeros((B, nc, H, c), jnp.float32)
    acc = jnp.zeros((B, nc, H, c, D), jnp.float32)
    v_diag = vs.transpose(0, 1, 3, 2, 4)  # (B,nc,H,c,D)
    m, l, acc = _online_update(m, l, acc, s, v_diag)

    # --- tree levels: unmasked cross attention, top half -> bottom half
    span = 2
    while span <= nc:
        nspans = nc // span
        half = span // 2
        sb = half * c  # bottom keys per span
        q_top = qs.reshape(B, nspans, span, c, H, D)[:, :, half:]
        k_bot = ks.reshape(B, nspans, span, c, H, D)[:, :, :half].reshape(B, nspans, sb, H, D)
        v_bot = vs.reshape(B, nspans, span, c, H, D)[:, :, :half].reshape(B, nspans, sb, H, D)
        s = jnp.einsum("bntqhd,bnkhd->bnthqk", q_top, k_bot,
                       preferred_element_type=jnp.float32) * scale  # (B,ns,half,H,c,sb)
        m_s = m.reshape(B, nspans, span, H, c)
        l_s = l.reshape(B, nspans, span, H, c)
        a_s = acc.reshape(B, nspans, span, H, c, D)
        v_b = v_bot.transpose(0, 1, 3, 2, 4)[:, :, None]  # (B,ns,1,H,sb,D)
        m_top, l_top, a_top = _online_update(
            m_s[:, :, half:], l_s[:, :, half:], a_s[:, :, half:], s, v_b)
        m = jnp.concatenate([m_s[:, :, :half], m_top], axis=2).reshape(B, nc, H, c)
        l = jnp.concatenate([l_s[:, :, :half], l_top], axis=2).reshape(B, nc, H, c)
        acc = jnp.concatenate([a_s[:, :, :half], a_top], axis=2).reshape(B, nc, H, c, D)
        span *= 2

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 1, 3, 2, 4).reshape(B, S, H, D).astype(q.dtype)


def local_band_attention(q, k, v, *, window: int) -> jax.Array:
    """Sliding-window causal attention with O(S*window) compute.

    q,k,v (B,S,H,D) (KV pre-expanded).  Chunk size == window: each query
    chunk attends its own chunk (causal mask) plus the previous chunk (band
    mask) — the standard band decomposition for Griffin/Mistral local attn.
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    c = min(window, S)
    assert S % c == 0, (S, c)
    nc = S // c

    ax = ("batch", None, None, "heads", None)
    qs = shard(q.reshape(B, nc, c, H, D), ax)
    ks = shard(k.reshape(B, nc, c, H, D), ax)
    vs = shard(v.reshape(B, nc, c, H, D), ax)
    kcat = jnp.concatenate([jnp.roll(ks, 1, axis=1), ks], axis=2)  # (B,nc,2c,H,D)
    vcat = jnp.concatenate([jnp.roll(vs, 1, axis=1), vs], axis=2)

    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qs, kcat,
                   preferred_element_type=jnp.float32) * scale  # (B,nc,H,c,2c)
    a = jnp.arange(c)
    b = jnp.arange(2 * c)
    rel = (a[:, None] + c) - b[None, :]  # qpos - kpos in the 2c concat frame
    base = (rel >= 0) & (rel < window)  # (c, 2c)
    mask = jnp.broadcast_to(base[None], (nc, c, 2 * c))
    first = jnp.broadcast_to((b >= c)[None, None, :], (1, c, 2 * c))
    mask = jnp.where((jnp.arange(nc) == 0)[:, None, None], mask & first, mask)
    s = jnp.where(mask[None, :, None, :, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bnhqk,bnkhd->bnhqd", p, vcat.astype(jnp.float32),
                     preferred_element_type=jnp.float32)  # (B,nc,H,c,D)
    return out.transpose(0, 1, 3, 2, 4).reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0) -> jax.Array:
    """Single-token attention against a cache.

    q (B,H,D); caches (B,Smax,KV,D); lengths (B,) = #valid positions.
    ``window`` > 0 marks a ring-buffer cache (local attention): all Smax
    slots are valid once the ring has wrapped.
    """
    B, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    Smax = k_cache.shape[1]
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    if window:
        # ring buffer: slot p holds a token iff p < length (not yet wrapped)
        # or always (wrapped).  lengths counts total tokens written.
        valid = (pos[None, :] < lengths[:, None]) | (lengths[:, None] >= Smax)
    else:
        valid = pos[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


# ----------------------------------------------------------------------- mlp


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def mlp_apply(params: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    dt = x.dtype
    if gated:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt),
                       preferred_element_type=jnp.float32)
        h = (act_fn(act)(g) * u).astype(dt)
    else:
        u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt),
                       preferred_element_type=jnp.float32)
        h = act_fn(act)(u).astype(dt)
    # bf16 output: halves the TP all-reduce wire bytes (see lm.py note)
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt),
                      preferred_element_type=dt).astype(dt)


def init_mlp(key, d: int, d_ff: int, gated: bool, out_scale: float = 1.0) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, (d, d_ff)),
        "w_down": dense_init(k2, (d_ff, d), scale=out_scale),
    }
    if gated:
        p["w_gate"] = dense_init(k3, (d, d_ff))
    return p


def dense_init(key, shape, scale: float = 1.0) -> jax.Array:
    fan_in = max(shape[-2] if len(shape) >= 2 else 1, 1)
    std = scale / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * std
