"""Unified decoder LM covering all 10 assigned architectures.

The layer stack is segmented for ``lax.scan``: a (possibly empty) unrolled
prefix (e.g. deepseek-moe's dense first layer), a scanned main body of
repeating pattern groups (e.g. Griffin's (rglru, rglru, local)), and an
unrolled tail for remainder layers.  Parameters for the main body are
stacked with a leading group dimension so the whole model compiles to one
program per distinct layer shape — essential to keep dry-run compile times
sane at 48 layers and to bound HLO size at scale.

Entry points:
- ``init_params``      — real parameter construction (smoke tests);
- ``forward``          — full-sequence logits (+ MoE aux loss): train/prefill;
- ``init_cache``       — decode cache/state pytree (abstract or concrete);
- ``decode_step``      — one-token serving step.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.ctx import shard
from . import recurrent as rec
from .layers import (
    apply_rope,
    blockwise_causal_attention,
    decode_attention,
    dense_init,
    init_mlp,
    init_rmsnorm,
    local_band_attention,
    mlp_apply,
    rmsnorm,
    rope_table,
)
from .moe import init_moe, moe_apply


@dataclass(frozen=True)
class ModelOptions:
    """Implementation knobs that do not change semantics (perf levers)."""

    q_chunk: int = 512
    kv_chunk: int = 1024
    tree_attention: bool = False  # binary-tree causal decomposition (§Perf)
    mlstm_chunk: int = 128
    compute_dtype: str = "bfloat16"
    moe_impl: Optional[str] = None  # override MoECfg.impl
    attn_recurrence: Optional[object] = None  # Pallas hooks (TPU path)
    mlstm_recurrence: Optional[object] = None

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)


# ----------------------------------------------------------- stack segmenting


@dataclass(frozen=True)
class LayerSpec:
    kind: str  # attn | local | rglru | mlstm | slstm
    use_moe: bool
    d_ff: int  # MLP width for this layer (0 = no MLP sub-block)


def layer_specs(cfg: ArchConfig) -> list:
    specs = []
    for i, kind in enumerate(cfg.layer_kinds):
        use_moe = cfg.moe is not None and i >= cfg.first_dense and kind in ("attn", "local")
        if use_moe:
            ff = 0
        elif cfg.moe is not None and i < cfg.first_dense:
            ff = cfg.first_dense_ff or cfg.d_ff
        elif kind in ("mlstm", "slstm"):
            ff = 0
        else:
            ff = cfg.d_ff
        specs.append(LayerSpec(kind, use_moe, ff))
    return specs


@dataclass(frozen=True)
class StackPlan:
    prefix: tuple  # tuple[LayerSpec]
    pattern: tuple  # tuple[LayerSpec] — one period
    num_groups: int
    tail: tuple  # tuple[LayerSpec]


def stack_plan(cfg: ArchConfig) -> StackPlan:
    specs = layer_specs(cfg)
    p = len(cfg.block_pattern)
    prefix = tuple(specs[: cfg.first_dense])
    rest = specs[cfg.first_dense:]
    # the rest must be periodic with period p (by construction of layer_kinds
    # when first_dense is a multiple of the pattern — enforce by assertion)
    num_groups = len(rest) // p
    pattern = tuple(rest[:p]) if num_groups else ()
    for g in range(num_groups):
        assert tuple(rest[g * p: (g + 1) * p]) == pattern, "stack not periodic"
    tail = tuple(rest[num_groups * p:])
    return StackPlan(prefix, pattern, num_groups, tail)


# ------------------------------------------------------------------- params


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": init_rmsnorm(d)}
    if spec.kind in ("attn", "local"):
        hd, H, KV = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
        attn = {
            "wq": dense_init(ks[0], (d, H, hd)),
            "wk": dense_init(ks[1], (d, KV, hd)),
            "wv": dense_init(ks[2], (d, KV, hd)),
            "wo": dense_init(ks[3], (H, hd, d), scale=1.0 / max(cfg.num_layers, 1) ** 0.5),
        }
        if cfg.qkv_bias:
            attn["bq"] = jnp.zeros((H, hd), jnp.float32)
            attn["bk"] = jnp.zeros((KV, hd), jnp.float32)
            attn["bv"] = jnp.zeros((KV, hd), jnp.float32)
        if cfg.qk_norm:
            attn["q_norm"] = init_rmsnorm(hd)
            attn["k_norm"] = init_rmsnorm(hd)
        p["attn"] = attn
    elif spec.kind == "rglru":
        p["rglru"] = rec.init_rglru(ks[0], d, cfg.d_rnn or d, cfg.conv_width)
    elif spec.kind == "mlstm":
        p["mlstm"] = rec.init_mlstm(ks[0], d, cfg.num_heads, cfg.conv_width)
    elif spec.kind == "slstm":
        p["slstm"] = rec.init_slstm(ks[0], d, cfg.num_heads)
    else:
        raise ValueError(spec.kind)
    if spec.use_moe:
        p["norm2"] = init_rmsnorm(d)
        p["moe"] = init_moe(ks[4], d, cfg.moe)
    elif spec.d_ff > 0:
        p["norm2"] = init_rmsnorm(d)
        p["mlp"] = init_mlp(ks[4], d, spec.d_ff, cfg.gated_mlp,
                            out_scale=1.0 / max(cfg.num_layers, 1) ** 0.5)
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    plan = stack_plan(cfg)
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": {"table": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model)) * cfg.d_model ** 0.5},
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": dense_init(ks[1], (cfg.d_model, cfg.padded_vocab))}
    if cfg.frontend:
        params["frontend"] = {"w": dense_init(ks[2], (cfg.frontend_dim, cfg.d_model))}
    params["prefix"] = [
        _init_layer(k, cfg, s)
        for k, s in zip(jax.random.split(ks[3], max(len(plan.prefix), 1)), plan.prefix)
    ]
    if plan.num_groups:
        def init_group(k):
            kk = jax.random.split(k, len(plan.pattern))
            return [_init_layer(kk[i], cfg, s) for i, s in enumerate(plan.pattern)]

        group_keys = jax.random.split(ks[4], plan.num_groups)
        per_group = [init_group(k) for k in group_keys]
        params["main"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
    else:
        params["main"] = []
    params["tail"] = [
        _init_layer(k, cfg, s)
        for k, s in zip(jax.random.split(ks[5], max(len(plan.tail), 1)), plan.tail)
    ]
    return params


def abstract_params(cfg: ArchConfig) -> dict:
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ------------------------------------------------------------------ forward


def _attention_block(aparams, cfg: ArchConfig, x, sin, cos, kind: str,
                     opts: ModelOptions, return_kv: bool = False):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, aparams["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k = jnp.einsum("bsd,dhe->bshe", x, aparams["wk"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bsd,dhe->bshe", x, aparams["wv"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    if "bq" in aparams:
        q = q + aparams["bq"].astype(dt)
        k = k + aparams["bk"].astype(dt)
        v = v + aparams["bv"].astype(dt)
    if "q_norm" in aparams:
        q = rmsnorm(q, aparams["q_norm"]["scale"], cfg.norm_eps)
        k = rmsnorm(k, aparams["k_norm"]["scale"], cfg.norm_eps)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # seq stays unsharded inside attention (under SP the residual stream is
    # seq-sharded; attention needs the full sequence per shard)
    q = shard(q, ("batch", None, "heads", None))
    kv_compact = (k, v)
    if cfg.q_groups > 1:
        # expand KV heads to the flattened H layout (kv head j -> query heads
        # j*G..j*G+G-1); flattened heads shard cleanly over the tensor axis
        k = jnp.repeat(k, cfg.q_groups, axis=2)
        v = jnp.repeat(v, cfg.q_groups, axis=2)
    k = shard(k, ("batch", None, "heads", None))
    v = shard(v, ("batch", None, "heads", None))
    if kind == "local":
        out = local_band_attention(q, k, v, window=cfg.window)
    else:
        out = blockwise_causal_attention(
            q, k, v, q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
            skip_masked_blocks=opts.tree_attention)
    out = shard(out, ("batch", None, "heads", None))
    # output in compute dtype: the TP partial-sum all-reduce rides on this
    # tensor, and bf16 wire bytes are half of f32 (per-shard accumulation
    # stays f32 inside the MXU)
    proj = jnp.einsum("bshe,hed->bsd", out, aparams["wo"].astype(dt),
                      preferred_element_type=dt).astype(dt)
    if return_kv:
        return proj, kv_compact  # un-expanded (B,S,KV,hd) for the cache
    return proj


def _pack_kv_cache(k, v, kind: str, cfg: ArchConfig, max_len: int):
    """Pack full-sequence K/V into the decode cache layout.

    Global attention: zero-padded (B, max_len, KV, hd) buffer.
    Local attention: ring buffer of size window, slot = position % window.
    """
    B, S = k.shape[:2]
    if kind == "local":
        w = min(cfg.window, max_len)
        n = min(S, w)
        pos = S - n + jnp.arange(n)
        slots = pos % w
        buf_k = jnp.zeros((B, w) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - n:])
        buf_v = jnp.zeros((B, w) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - n:])
        return {"k": buf_k, "v": buf_v}
    pad = max_len - S
    assert pad >= 0, (S, max_len)
    buf_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    buf_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": buf_k, "v": buf_v}


def _apply_layer_seq(lparams, cfg: ArchConfig, spec: LayerSpec, x, sin, cos,
                     opts: ModelOptions, want_state: bool = False,
                     max_len: int = 0):
    """One layer over a full sequence.  Returns (x, aux_loss[, state])."""
    aux = jnp.zeros((), jnp.float32)
    state = None
    h = rmsnorm(x, lparams["norm1"]["scale"], cfg.norm_eps)
    if spec.kind in ("attn", "local"):
        if want_state:
            mix, (kk, vv) = _attention_block(lparams["attn"], cfg, h, sin, cos,
                                             spec.kind, opts, return_kv=True)
            state = _pack_kv_cache(kk, vv, spec.kind, cfg, max_len)
        else:
            mix = _attention_block(lparams["attn"], cfg, h, sin, cos, spec.kind, opts)
    elif spec.kind == "rglru":
        r = rec.rglru_seq(lparams["rglru"], h, return_state=want_state)
        mix, state = r if want_state else (r, None)
    elif spec.kind == "mlstm":
        r = rec.mlstm_seq(lparams["mlstm"], h, cfg.num_heads,
                          chunk=opts.mlstm_chunk,
                          recurrence=opts.mlstm_recurrence,
                          return_state=want_state)
        mix, state = r if want_state else (r, None)
    elif spec.kind == "slstm":
        r = rec.slstm_seq(lparams["slstm"], h, cfg.num_heads,
                          return_state=want_state)
        mix, state = r if want_state else (r, None)
    else:
        raise ValueError(spec.kind)
    x = x + mix
    x = shard(x, ("batch", "seq", "embed"))
    if spec.use_moe:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        m = cfg.moe if opts.moe_impl is None else cfg.moe.__class__(
            **{**cfg.moe.__dict__, "impl": opts.moe_impl})
        out, aux = moe_apply(lparams["moe"], h2, m, cfg.act)
        x = x + out
    elif spec.d_ff > 0:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_apply(lparams["mlp"], h2, cfg.act, cfg.gated_mlp)
    x = shard(x, ("batch", "seq", "embed"))
    if want_state:
        return x, aux, state
    return x, aux


def embed_inputs(params, cfg: ArchConfig, tokens, frontend_embeds, dtype):
    """tokens (B,S_tok) int32; frontend_embeds (B,F,frontend_dim) or None."""
    table = params["embed"]["table"]
    x = table.astype(dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if cfg.frontend:
        fe = jnp.einsum("bfe,ed->bfd", frontend_embeds.astype(dtype),
                        params["frontend"]["w"].astype(dtype))
        x = jnp.concatenate([fe, x], axis=1)
    return x


def forward(params, cfg: ArchConfig, tokens, frontend_embeds=None,
            opts: ModelOptions = ModelOptions(), remat: bool = False):
    """Full-sequence forward.  Returns (logits (B,S,V) f32, aux_loss)."""
    plan = stack_plan(cfg)
    dt = opts.dtype
    x = embed_inputs(params, cfg, tokens, frontend_embeds, dt)
    x = shard(x, ("batch", "seq", "embed"))
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    aux_total = jnp.zeros((), jnp.float32)

    def run_layer(lp, spec, x):
        return _apply_layer_seq(lp, cfg, spec, x, sin, cos, opts)

    for lp, spec in zip(params["prefix"], plan.prefix):
        x, aux = run_layer(lp, spec, x)
        aux_total = aux_total + aux

    if plan.num_groups:
        def group_body(carry, group_params):
            x, aux_total = carry
            for i, spec in enumerate(plan.pattern):
                x, aux = run_layer(group_params[i], spec, x)
                aux_total = aux_total + aux
            return (x, aux_total), None

        body = group_body
        if remat:
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["main"])

    for lp, spec in zip(params["tail"], plan.tail):
        x, aux = run_layer(lp, spec, x)
        aux_total = aux_total + aux

    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"]["w"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt),
                        preferred_element_type=jnp.float32)
    logits = shard(logits, ("batch", None, "vocab"))  # vocab wins under SP
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = _mask_padded_vocab(logits, cfg)
    return logits, aux_total


def _mask_padded_vocab(logits, cfg: ArchConfig):
    """Padded vocab columns are masked to -inf: function-preserving padding."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    col = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(col, logits, -1e30)


def forward_with_cache(params, cfg: ArchConfig, tokens, frontend_embeds=None,
                       max_len: int = 0, opts: ModelOptions = ModelOptions()):
    """Prefill: full-sequence forward that also builds the decode cache.

    Returns (logits (B,S,V) f32, cache) with cache['len'] set to the full
    sequence length (frontend prefix included).
    """
    plan = stack_plan(cfg)
    dt = opts.dtype
    x = embed_inputs(params, cfg, tokens, frontend_embeds, dt)
    x = shard(x, ("batch", "seq", "embed"))
    B, S = x.shape[:2]
    max_len = max(max_len, S)
    positions = jnp.arange(S)[None, :]
    sin, cos = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    aux_total = jnp.zeros((), jnp.float32)
    cache = {"prefix": [], "tail": [], "main": [],
             "len": jnp.full((B,), S, jnp.int32)}

    for lp, spec in zip(params["prefix"], plan.prefix):
        x, aux, st = _apply_layer_seq(lp, cfg, spec, x, sin, cos, opts,
                                      want_state=True, max_len=max_len)
        aux_total = aux_total + aux
        cache["prefix"].append(st)

    if plan.num_groups:
        def group_body(carry, group_params):
            x, aux_total = carry
            states = []
            for i, spec in enumerate(plan.pattern):
                x, aux, st = _apply_layer_seq(group_params[i], cfg, spec, x,
                                              sin, cos, opts, want_state=True,
                                              max_len=max_len)
                aux_total = aux_total + aux
                states.append(st)
            return (x, aux_total), states

        (x, aux_total), main_states = jax.lax.scan(group_body, (x, aux_total),
                                                   params["main"])
        cache["main"] = main_states

    for lp, spec in zip(params["tail"], plan.tail):
        x, aux, st = _apply_layer_seq(lp, cfg, spec, x, sin, cos, opts,
                                      want_state=True, max_len=max_len)
        aux_total = aux_total + aux
        cache["tail"].append(st)

    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"]["w"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return _mask_padded_vocab(logits, cfg), cache


# -------------------------------------------------------------------- decode


def _init_layer_state(cfg: ArchConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype):
    if spec.kind == "attn":
        return {
            "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    if spec.kind == "local":
        w = min(cfg.window, max_len)
        return {
            "k": jnp.zeros((batch, w, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, w, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    if spec.kind == "rglru":
        return rec.rglru_init_state(batch, cfg.d_rnn or cfg.d_model, cfg.conv_width, dtype)
    if spec.kind == "mlstm":
        return rec.mlstm_init_state(batch, cfg.d_model, cfg.num_heads, cfg.conv_width, dtype)
    if spec.kind == "slstm":
        return rec.slstm_init_state(batch, cfg.d_model)
    raise ValueError(spec.kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    plan = stack_plan(cfg)
    cache = {
        "prefix": [_init_layer_state(cfg, s, batch, max_len, dtype) for s in plan.prefix],
        "tail": [_init_layer_state(cfg, s, batch, max_len, dtype) for s in plan.tail],
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if plan.num_groups:
        one = [_init_layer_state(cfg, s, batch, max_len, dtype) for s in plan.pattern]
        cache["main"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (plan.num_groups,) + x.shape).copy(), one)
    else:
        cache["main"] = []
    return cache


def _decode_layer(lparams, cfg: ArchConfig, spec: LayerSpec, state, x, sin, cos,
                  lengths, opts: ModelOptions):
    """One layer, one token.  x (B,d).  Returns (x, new_state)."""
    dt = x.dtype
    h = rmsnorm(x, lparams["norm1"]["scale"], cfg.norm_eps)
    if spec.kind in ("attn", "local"):
        ap = lparams["attn"]
        q = jnp.einsum("bd,dhe->bhe", h, ap["wq"].astype(dt))
        k = jnp.einsum("bd,dhe->bhe", h, ap["wk"].astype(dt))
        v = jnp.einsum("bd,dhe->bhe", h, ap["wv"].astype(dt))
        if "bq" in ap:
            q, k, v = q + ap["bq"].astype(dt), k + ap["bk"].astype(dt), v + ap["bv"].astype(dt)
        if "q_norm" in ap:
            q = rmsnorm(q, ap["q_norm"]["scale"], cfg.norm_eps)
            k = rmsnorm(k, ap["k_norm"]["scale"], cfg.norm_eps)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        Smax = state["k"].shape[1]
        # local: ring buffer; global: clamp (dry-run decodes the final slot)
        slot = lengths % Smax if spec.kind == "local" else jnp.minimum(lengths, Smax - 1)
        bidx = jnp.arange(x.shape[0])
        new_k = state["k"].at[bidx, slot].set(k)
        new_v = state["v"].at[bidx, slot].set(v)
        window = cfg.window if spec.kind == "local" else 0
        out = decode_attention(q, new_k, new_v, lengths + 1, window=window)
        mix = jnp.einsum("bhe,hed->bd", out, ap["wo"].astype(dt))
        new_state = {"k": new_k, "v": new_v}
    elif spec.kind == "rglru":
        mix, new_state = rec.rglru_step(lparams["rglru"], h, state)
    elif spec.kind == "mlstm":
        mix, new_state = rec.mlstm_step(lparams["mlstm"], h, state, cfg.num_heads)
    elif spec.kind == "slstm":
        mix, new_state = rec.slstm_step(lparams["slstm"], h, state, cfg.num_heads)
    else:
        raise ValueError(spec.kind)
    x = x + mix
    if spec.use_moe:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        out, _ = moe_apply(lparams["moe"], h2[:, None, :], cfg.moe, cfg.act)
        x = x + out[:, 0]
    elif spec.d_ff > 0:
        h2 = rmsnorm(x, lparams["norm2"]["scale"], cfg.norm_eps)
        x = x + mlp_apply(lparams["mlp"], h2, cfg.act, cfg.gated_mlp)
    return x, new_state


def decode_step(params, cfg: ArchConfig, cache, tokens,
                opts: ModelOptions = ModelOptions()):
    """One serving step: tokens (B,) int32 -> (logits (B,V) f32, new cache).

    ``cache['len']`` (B,) is the number of tokens already in context.
    """
    plan = stack_plan(cfg)
    dt = opts.dtype
    lengths = cache["len"]
    x = params["embed"]["table"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = shard(x, ("batch", "embed"))
    sin, cos = rope_table(lengths, cfg.head_dim, cfg.rope_theta)
    new_cache = {"len": lengths + 1, "prefix": [], "tail": [], "main": cache["main"]}

    for lp, spec, st in zip(params["prefix"], plan.prefix, cache["prefix"]):
        x, ns = _decode_layer(lp, cfg, spec, st, x, sin, cos, lengths, opts)
        new_cache["prefix"].append(ns)

    if plan.num_groups:
        def group_body(x, scanned):
            group_params, group_state = scanned
            new_states = []
            for i, spec in enumerate(plan.pattern):
                x, ns = _decode_layer(group_params[i], cfg, spec, group_state[i],
                                      x, sin, cos, lengths, opts)
                new_states.append(ns)
            return x, new_states

        x, new_main = jax.lax.scan(group_body, x, (params["main"], cache["main"]))
        new_cache["main"] = new_main

    for lp, spec, st in zip(params["tail"], plan.tail, cache["tail"]):
        x, ns = _decode_layer(lp, cfg, spec, st, x, sin, cos, lengths, opts)
        new_cache["tail"].append(ns)

    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    head = (params["embed"]["table"].T if cfg.tie_embeddings else params["head"]["w"])
    logits = jnp.einsum("bd,dv->bv", x, head.astype(dt),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return _mask_padded_vocab(logits, cfg), new_cache


# --------------------------------------------------------------------- loss


def loss_fn(params, cfg: ArchConfig, batch: dict,
            opts: ModelOptions = ModelOptions(), remat: bool = True):
    """batch: tokens (B,S), labels (B,S) (-1 = masked), optional frontend."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend_embeds"), opts, remat=remat)
    labels = batch["labels"]
    if cfg.frontend:
        # frontend prefix positions carry no labels
        logits = logits[:, cfg.frontend_len:]
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    total = loss + aux_w * aux
    return total, {"ce_loss": loss, "aux_loss": aux,
                   "tokens": jnp.sum(mask)}
