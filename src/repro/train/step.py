"""Training step construction: pjit-ready, remat'd, optionally compressed.

``make_train_step`` builds a pure (state, batch) -> (state, metrics) function
suitable for jax.jit with in/out shardings from ``train_state_specs``.

Variants:
- baseline: global loss over the ('pod','data')-sharded batch; XLA inserts
  the gradient reduce automatically (paper-faithful: let the platform own
  communication).
- grad accumulation: lax.scan over microbatches.
- compressed cross-pod sync: partial-manual shard_map over the 'pod' axis,
  per-pod grads combined with int8+EF all-gather (see train/compress.py) —
  the beyond-paper collective-term optimization (§Perf).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import ModelOptions, init_params, loss_fn
from ..sharding.ctx import use_rules
from ..sharding.specs import PARAM_RULES, param_specs
from .compress import compressed_mean_over_axis, init_ef_state
from .optim import OptimizerConfig, adamw_update, clip_by_global_norm, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    accum_steps: int = 1
    compress_pod_grads: bool = False
    num_pods: int = 1
    remat: bool = True


def init_train_state(key, cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()) -> dict:
    params = init_params(key, cfg)
    state = {
        "params": params,
        "opt": init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.compress_pod_grads:
        state["ef"] = init_ef_state(params, tcfg.num_pods)
    return state


def abstract_train_state(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()):
    return jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg, tcfg))


def train_state_specs(state, mesh: Mesh, rules: dict = PARAM_RULES):
    """NamedShardings for a (possibly abstract) train state."""
    p_specs = param_specs(state["params"], mesh, rules)
    specs = {
        "params": p_specs,
        "opt": {"m": p_specs, "v": p_specs},
        "step": NamedSharding(mesh, P()),
    }
    if "ef" in state:
        # EF buffers: leading pod dim + the parameter's own sharding —
        # without the param-dim sharding every device would hold a full
        # per-pod gradient replica (measured: 50x memory-term blowup)
        from ..sharding.specs import fit_spec, logical_to_spec, param_logical_axes

        logical = param_logical_axes(state["params"])

        def ef_spec(leaf, ax):
            spec = logical_to_spec(ax, rules)
            spec = fit_spec(spec, leaf.shape[1:], mesh)
            return NamedSharding(mesh, P(*(("pod",) + tuple(spec))))

        specs["ef"] = jax.tree.map(
            ef_spec, state["ef"], logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
    return specs


def batch_sharding(mesh: Mesh, batch, data_axes: tuple = ("pod", "data")):
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    return jax.tree.map(lambda _: NamedSharding(mesh, P(axes)), batch)


def _grads_and_metrics(params, batch, cfg, opts, remat, accum_steps):
    def lf(p, b):
        return loss_fn(p, cfg, b, opts, remat=remat)

    if accum_steps <= 1:
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
        return grads, loss, metrics

    B = batch["tokens"].shape[0]
    assert B % accum_steps == 0
    micro = jax.tree.map(
        lambda x: x.reshape((accum_steps, B // accum_steps) + x.shape[1:]), batch)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, mb)
        acc = jax.tree.map(jnp.add, acc, grads)
        return (acc, loss_acc + loss), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), _ = jax.lax.scan(body, (zero, jnp.zeros((), jnp.float32)), micro)
    grads = jax.tree.map(lambda g: g / accum_steps, gsum)
    loss = loss_sum / accum_steps
    return grads, loss, {"ce_loss": loss, "aux_loss": jnp.zeros(()), "tokens": jnp.zeros(())}


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig(),
                    opts: ModelOptions = ModelOptions(),
                    mesh: Optional[Mesh] = None,
                    act_rules: Optional[dict] = None):
    """Returns step(state, batch) -> (state, metrics)."""
    ocfg = tcfg.optimizer

    def apply_update(state, grads, loss, metrics):
        grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
        new_params, new_opt = adamw_update(ocfg, state["params"], grads,
                                           state["opt"], state["step"])
        new_state = dict(state)
        new_state.update(params=new_params, opt=new_opt, step=state["step"] + 1)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out_metrics

    if not tcfg.compress_pod_grads:
        def step(state, batch):
            ctx = use_rules(mesh, act_rules) if (mesh is not None and act_rules) else None
            if ctx is not None:
                with ctx:
                    grads, loss, metrics = _grads_and_metrics(
                        state["params"], batch, cfg, opts, tcfg.remat, tcfg.accum_steps)
            else:
                grads, loss, metrics = _grads_and_metrics(
                    state["params"], batch, cfg, opts, tcfg.remat, tcfg.accum_steps)
            return apply_update(state, grads, loss, metrics)

        return step

    # --- compressed cross-pod variant -------------------------------------
    # Pure-pjit formulation (partial-manual shard_map lowering is fragile):
    # gradients are computed per pod-group via vmap over a pod-sharded
    # leading dim; EF + int8 quantization are elementwise on that dim (stay
    # pod-local); only the final dequant-mean crosses pods — and its
    # all-gather operand is the int8 tensor, which is the wire saving.
    assert mesh is not None and "pod" in mesh.axis_names
    npods = mesh.shape["pod"]
    inner_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
    _prules = {k: v for k, v in PARAM_RULES.items()
               if (v in mesh.axis_names if isinstance(v, str) else True)}

    def step(state, batch):
        params = state["params"]
        micro = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape((npods, x.shape[0] // npods) + x.shape[1:]),
                NamedSharding(mesh, P("pod", inner_axes))),
            batch)

        def lf(p, b):
            return loss_fn(p, cfg, b, opts, remat=tcfg.remat)

        def gfn(b):
            (loss, _metrics), g = jax.value_and_grad(lf, has_aux=True)(params, b)
            return g, loss

        grads_g, losses = jax.vmap(gfn)(micro)  # (npods, ...) pod-sharded
        # pin grads_g to pod+param sharding (mirrors the EF buffers)
        from ..sharding.specs import fit_spec, logical_to_spec, param_logical_axes
        from .compress import ef_quantize_mean

        logical = param_logical_axes(params)
        prules = _prules

        def pin(leaf, ax):
            spec = fit_spec(logical_to_spec(ax, prules), leaf.shape[1:], mesh)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, P(*(("pod",) + tuple(spec)))))

        grads_g = jax.tree.map(
            pin, grads_g, logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))
        mean_grads, new_ef = ef_quantize_mean(grads_g, state["ef"])
        loss = jnp.mean(losses)
        metrics = {"ce_loss": loss, "aux_loss": jnp.zeros(()),
                   "tokens": jnp.zeros(())}
        new_state, out_metrics = apply_update(state, mean_grads, loss, metrics)
        new_state["ef"] = new_ef
        return new_state, out_metrics

    return step
