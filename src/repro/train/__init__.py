from .optim import OptimizerConfig, adamw_update, clip_by_global_norm, init_opt_state
from .step import (
    TrainConfig,
    abstract_train_state,
    batch_sharding,
    init_train_state,
    make_train_step,
    train_state_specs,
)

__all__ = [
    "OptimizerConfig",
    "TrainConfig",
    "abstract_train_state",
    "adamw_update",
    "batch_sharding",
    "clip_by_global_norm",
    "init_opt_state",
    "init_train_state",
    "make_train_step",
    "train_state_specs",
]
