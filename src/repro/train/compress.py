"""Cross-pod gradient compression (int8 + error feedback).

Cross-pod links are the scarcest bandwidth at multi-pod scale (the paper's
networking-latency lesson, §8, transposed to ICI/DCN).  Pods are pure
data-parallel replicas, so the only cross-pod traffic is the gradient
combine; quantizing it to int8 cuts wire bytes 4x vs f32 (2x vs bf16).

Mechanism: per-tensor symmetric int8 quantization with an error-feedback
buffer (residual accumulation), combined via all-gather of the quantized
payloads + per-pod scales, dequantize-and-mean locally.  The EF buffer keeps
the scheme unbiased over time (Seide et al. 1-bit SGD; Karimireddy et al.
EF-SGD).  EF state is per-pod: stored with a leading pod axis in the train
state, sharded P('pod').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    """Symmetric per-tensor int8.  Returns (q int8, scale f32 scalar)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_mean_over_axis(grads, ef, axis_name: str):
    """Inside shard_map(manual over ``axis_name``): EF-compressed mean.

    grads/ef: matching pytrees (per-pod local values).
    Returns (mean_grads f32, new_ef).
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        new_e = corrected - q.astype(jnp.float32) * scale
        qs = jax.lax.all_gather(q, axis_name)  # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
        return jnp.mean(deq, axis=0), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(tree, [o[1] for o in outs])
    return mean, new_ef


def init_ef_state(params, num_pods: int):
    """Error-feedback buffers, one per pod (leading pod axis)."""
    return jax.tree.map(
        lambda p: jnp.zeros((num_pods,) + p.shape, jnp.float32), params)


def ef_quantize_mean(grads_g, ef):
    """Pure-pjit EF-compressed cross-pod gradient combine.

    grads_g / ef: pytrees with leading pod dim (npods, ...), sharded
    P('pod', ...).  Everything except the final mean is elementwise over
    the pod dim (pod-local); the mean's gathered operand is int8, so the
    cross-pod wire traffic is 1 byte/element + one scale per tensor per pod.
    Returns (mean_grads (no pod dim), new_ef (pod dim)).
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        red_axes = tuple(range(1, corrected.ndim))
        amax = jnp.max(jnp.abs(corrected), axis=red_axes, keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / 127.0  # (npods, 1, 1, ...)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        mean = jnp.mean(q.astype(jnp.float32) * scale, axis=0)
        return mean, new_e

    flat_g, tree = jax.tree.flatten(grads_g)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in outs]),
            jax.tree.unflatten(tree, [o[1] for o in outs]))
