"""AdamW optimizer over parameter pytrees, with global-norm clipping.

Pure-pytree implementation (no optax dependency): the optimizer state is
sharded exactly like the parameters (specs derive from the same logical
axes — see repro.sharding.specs), which is what makes FSDP-style
optimizer-state sharding automatic under pjit.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros(), "v": zeros()}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, clip: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * factor.astype(g.dtype), grads), norm


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state)."""
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        return (p32 - lr * step_dir).astype(p.dtype), m_new, v_new

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}
