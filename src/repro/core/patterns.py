"""The paper's four cloud-native patterns (§4): controllers, conductors,
coordinators, and the causal chains that emerge from their composition.

- A **Controller** is a control loop tracking a *single* resource kind.  It
  keeps a reflector cache of that kind and reacts to ADDED / MODIFIED /
  DELETED events via ``on_addition`` / ``on_modification`` / ``on_deletion``.
- A **Conductor** observes events from *multiple* kinds.  It owns no
  resources and keeps only recomputable local state; it registers with the
  controllers of the kinds it cares about and receives the same
  notifications each controller does (paper §4.2).
- A **Coordinator** serializes modifications to a resource kind behind a
  single writer (multiple-reader / single-writer, paper §4.3).
- A **causal chain** (paper §4.4) is not a class: it is the emergent
  composition of links where one actor's synchronous change to a resource it
  owns triggers — through event delivery — the next actor's change.
  ``CausalTrace`` makes chains observable for tests and debugging.

Determinism claim (paper §4): controllers + conductors compose into a state
machine; adding coordinators (single-writer serialization) makes that state
machine deterministic even though event delivery is asynchronous.  The
property tests in ``tests/test_core_patterns.py`` exercise exactly this:
random interleavings of event delivery must converge to the same final state.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Iterable, Optional

from .resources import (
    ConflictError,
    Event,
    EventType,
    NotFoundError,
    Resource,
    ResourceStore,
)


class CausalTrace:
    """Records (actor, action, resource, detail) tuples so causal chains can
    be asserted on in tests and rendered for debugging.

    ``entries`` is a bounded ring (``maxlen`` records): a long-lived harness
    keeps only the most recent window instead of growing without limit.  The
    default is large enough that no single test scenario ever evicts — the
    single-writer property tests iterate the full run's entries.
    """

    def __init__(self, maxlen: int | None = 100_000) -> None:
        self._lock = threading.Lock()
        self.entries: deque[tuple[str, str, tuple, str]] = deque(maxlen=maxlen)

    def record(self, actor: str, action: str, key: tuple, detail: str = "") -> None:
        with self._lock:
            self.entries.append((actor, action, key, detail))

    def actors_for(self, key: tuple) -> list[str]:
        with self._lock:
            return [a for (a, _, k, _) in self.entries if k == key]

    def chain(self) -> list[str]:
        with self._lock:
            return [f"{a}:{act}:{k[0]}/{k[2]}{(':' + d) if d else ''}" for (a, act, k, d) in self.entries]

    def clear(self) -> None:
        with self._lock:
            self.entries.clear()


class EventListener:
    """Anything that can receive categorized resource events."""

    name: str = "listener"

    def handle_event(self, event: Event) -> None:
        raise NotImplementedError


class Controller(EventListener):
    """Control loop over a single resource kind, with a reflector cache.

    Subclasses override the three callbacks.  Conductors register themselves
    via ``add_listener`` and are forwarded every event *after* the
    controller's own handling (so the conductor observes the same stream, and
    the controller's cache is already current when conductors run).
    """

    def __init__(self, store: ResourceStore, kind: str, namespace: Optional[str] = None,
                 name: Optional[str] = None, trace: Optional[CausalTrace] = None):
        self.store = store
        self.kind = kind
        self.namespace = namespace
        self.name = name or f"{kind.lower()}-controller"
        self.trace = trace
        self.cache: dict[tuple, Resource] = {}
        self._listeners: list[EventListener] = []
        self._last_seq = 0

    # -- wiring ---------------------------------------------------------

    def add_listener(self, listener: "EventListener") -> None:
        self._listeners.append(listener)

    def handle_event(self, event: Event) -> None:
        if event.resource.kind != self.kind:
            return
        if self.namespace is not None and event.resource.namespace != self.namespace:
            return
        if event.seq <= self._last_seq:  # duplicate-delivery guard (at-least-once)
            return
        self._last_seq = event.seq
        res = event.resource
        if event.type == EventType.ADDED:
            self.cache[res.key] = res
            self._record("observe-add", res.key)
            self.on_addition(res)
        elif event.type == EventType.MODIFIED:
            old = self.cache.get(res.key, event.old)
            self.cache[res.key] = res
            self._record("observe-mod", res.key)
            self.on_modification(old, res)
        elif event.type == EventType.DELETED:
            self.cache.pop(res.key, None)
            self._record("observe-del", res.key)
            self.on_deletion(res)
        for listener in self._listeners:
            listener.handle_event(event)

    def _record(self, action: str, key: tuple, detail: str = "") -> None:
        if self.trace is not None:
            self.trace.record(self.name, action, key, detail)

    # -- callbacks (override) --------------------------------------------

    def on_addition(self, res: Resource) -> None:  # pragma: no cover - default
        pass

    def on_modification(self, old: Optional[Resource], new: Resource) -> None:  # pragma: no cover
        pass

    def on_deletion(self, res: Resource) -> None:  # pragma: no cover - default
        pass


class Conductor(EventListener):
    """Observes multiple kinds, drives a state machine toward a goal.

    Holds only *recomputable* state (paper: the subscription board, job
    submission progress).  ``kinds`` documents what it listens to; actual
    delivery comes from the controllers it registers with.
    """

    kinds: tuple[str, ...] = ()

    def __init__(self, store: ResourceStore, name: Optional[str] = None,
                 trace: Optional[CausalTrace] = None):
        self.store = store
        self.name = name or f"{type(self).__name__.lower()}"
        self.trace = trace
        self._seen: dict[str, int] = {}

    def handle_event(self, event: Event) -> None:
        if self.kinds and event.resource.kind not in self.kinds:
            return
        # Conductors can be registered with several controllers that observe
        # overlapping streams; dedupe on the global sequence number per kind.
        last = self._seen.get(event.resource.kind, 0)
        if event.seq <= last:
            return
        self._seen[event.resource.kind] = event.seq
        self._record("observe", event.resource.key, event.type.value)
        self.on_event(event)

    def _record(self, action: str, key: tuple, detail: str = "") -> None:
        if self.trace is not None:
            self.trace.record(self.name, action, key, detail)

    def on_event(self, event: Event) -> None:  # pragma: no cover - override
        pass


class Coordinator:
    """Single-writer command queue for one resource kind (paper §4.3).

    Any actor may ``submit`` a mutation command; commands execute serially
    under the coordinator's lock, giving multiple-reader/single-writer
    semantics and eliminating CAS races between concurrent agents.
    """

    def __init__(self, store: ResourceStore, kind: str, namespace: str = "default",
                 name: Optional[str] = None, trace: Optional[CausalTrace] = None):
        self.store = store
        self.kind = kind
        self.namespace = namespace
        self.name = name or f"{kind.lower()}-coordinator"
        self.trace = trace
        # public: the ApiClient serializes creates/deletes of this kind
        # against the command stream by holding the same writer lock
        self.lock = threading.Lock()

    def submit(self, name: str, command: Callable[[Resource], None],
               requester: str = "?") -> Optional[Resource]:
        """Serially execute ``command`` against the named resource.

        Returns the updated resource, or None if it does not exist (a command
        against a deleted resource is a no-op, matching controller semantics
        for stale events).
        """
        with self.lock:
            try:
                res = self.store.update(self.kind, name, command, namespace=self.namespace)
            except NotFoundError:
                return None
            if self.trace is not None:
                self.trace.record(self.name, "modify", res.key, f"for={requester}")
            return res

    def submit_status(self, name: str, patch: dict, requester: str = "?") -> Optional[Resource]:
        def command(res: Resource) -> None:
            res.status.update(patch)

        return self.submit(name, command, requester=requester)


class Runtime:
    """Drives event delivery from the store to registered listeners.

    Two modes:

    - ``threaded``: one daemon thread per controller draining its own watch
      subscription — the realistic asynchronous deployment (each controller
      is an independent actor, as in the paper's instance operator).
    - ``manual`` (deterministic): no threads; ``step()``/``drain()`` deliver
      events in a caller-controlled order.  Property tests use this to
      explore adversarial interleavings and assert convergence.
    """

    def __init__(self, store: ResourceStore, threaded: bool = True):
        self.store = store
        self.threaded = threaded
        self._controllers: list[Controller] = []
        self._subs: list = []
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()

    def register(self, controller: Controller, replay: bool = True) -> None:
        sub = self.store.watch(kinds=(controller.kind,), namespace=controller.namespace,
                               replay=replay)
        self._controllers.append(controller)
        self._subs.append(sub)
        if self.threaded:
            t = threading.Thread(
                target=self._run_loop, args=(controller, sub),
                name=f"runtime-{controller.name}", daemon=True,
            )
            self._threads.append(t)
            t.start()

    def _run_loop(self, controller: Controller, sub) -> None:
        while not self._stop.is_set():
            ev = sub.take(timeout=0.05)
            if ev is None:
                continue
            try:
                controller.handle_event(ev)
            except Exception as exc:  # noqa: BLE001 - controller crash should not kill runtime
                import traceback

                traceback.print_exc()
                if controller.trace is not None:
                    controller.trace.record(controller.name, "error", ev.resource.key, repr(exc))

    # -- deterministic mode ----------------------------------------------

    def pending(self) -> list[int]:
        """Queue depths per controller (manual mode introspection)."""
        return [len(sub) for sub in self._subs]

    def step(self, index: Optional[int] = None) -> bool:
        """Deliver one event.  ``index`` selects which controller's queue;
        default picks the queue whose head has the lowest global seq (the
        canonical total-order schedule)."""
        assert not self.threaded, "step() is for manual runtimes"
        if index is None:
            heads = [(seq, i) for i, sub in enumerate(self._subs)
                     if (seq := sub.head_seq()) is not None]
            if not heads:
                return False
            index = min(heads)[1]
        sub = self._subs[index]
        ev = sub.poll()
        if ev is None:
            return False
        self._controllers[index].handle_event(ev)
        return True

    def drain(self, max_steps: int = 100000, order: Optional[Callable[[list[int]], int]] = None) -> int:
        """Deliver events until quiescent.  ``order`` maps the list of
        non-empty queue indices to the index to service next — the hook the
        interleaving property tests use."""
        assert not self.threaded, "drain() is for manual runtimes"
        steps = 0
        while steps < max_steps:
            nonempty = [i for i, sub in enumerate(self._subs) if len(sub)]
            if not nonempty:
                return steps
            idx = nonempty[0] if order is None else order(nonempty)
            self.step(idx)
            steps += 1
        raise RuntimeError("runtime did not quiesce (possible event loop)")

    def quiescent(self) -> bool:
        return all(len(sub) == 0 for sub in self._subs)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
        for sub in self._subs:
            self.store.unwatch(sub)
        self._threads.clear()


__all__ = [
    "CausalTrace",
    "Conductor",
    "ConflictError",
    "Controller",
    "Coordinator",
    "Event",
    "EventListener",
    "EventType",
    "Resource",
    "ResourceStore",
    "Runtime",
]
