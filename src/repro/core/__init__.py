"""Cloud-native patterns core: the paper's primary contribution, reusable.

Exports the resource substrate (store/events) and the four patterns
(controller, conductor, coordinator; causal chains via CausalTrace).
"""

from .patterns import (
    CausalTrace,
    Conductor,
    Controller,
    Coordinator,
    Event,
    EventListener,
    EventType,
    Resource,
    ResourceStore,
    Runtime,
)
from .resources import (
    FOREGROUND_FINALIZER,
    AlreadyExistsError,
    ConflictError,
    NotFoundError,
    OwnerRef,
    Subscription,
    TerminatingError,
    condition_is,
    get_condition,
    set_condition,
    wait_for,
)

__all__ = [
    "AlreadyExistsError",
    "CausalTrace",
    "Conductor",
    "ConflictError",
    "Controller",
    "Coordinator",
    "Event",
    "EventListener",
    "EventType",
    "FOREGROUND_FINALIZER",
    "NotFoundError",
    "OwnerRef",
    "Resource",
    "ResourceStore",
    "Runtime",
    "Subscription",
    "TerminatingError",
    "condition_is",
    "get_condition",
    "set_condition",
    "wait_for",
]
