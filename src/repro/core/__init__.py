"""Cloud-native patterns core: the paper's primary contribution, reusable.

Exports the resource substrate (store/events) and the four patterns
(controller, conductor, coordinator; causal chains via CausalTrace).
"""

from .patterns import (
    CausalTrace,
    Conductor,
    Controller,
    Coordinator,
    Event,
    EventListener,
    EventType,
    Resource,
    ResourceStore,
    Runtime,
)
from .resources import (
    AlreadyExistsError,
    ConflictError,
    NotFoundError,
    OwnerRef,
    Subscription,
    wait_for,
)

__all__ = [
    "AlreadyExistsError",
    "CausalTrace",
    "Conductor",
    "ConflictError",
    "Controller",
    "Coordinator",
    "Event",
    "EventListener",
    "EventType",
    "NotFoundError",
    "OwnerRef",
    "Resource",
    "ResourceStore",
    "Runtime",
    "Subscription",
    "wait_for",
]
