"""Versioned resource store — the framework's 'kube-api-server + etcd'.

The paper's architecture rests on Kubernetes providing *state-as-a-service*:
persistent, versioned objects with reliable, totally-ordered change
notifications (paper §3.3, §7.4).  This module provides that substrate:

- ``Resource``: a named, versioned object with ``spec`` (desired state) and
  ``status`` (observed state), labels, and owner references.
- ``ResourceStore``: thread-safe CRUD with optimistic concurrency
  (compare-and-swap on ``resource_version``), a total-order event log,
  watch subscriptions with full-history replay (what lets the instance
  operator recover by catching up — paper §5.3), label selectors,
  owner-reference garbage collection (and the paper's §8 mitigation:
  bulk deletion by label), and an optional write-ahead log for durability.

Nothing in here knows about streams, jobs, or JAX: it is the generic
substrate the cloud-native patterns (controller / conductor / coordinator /
causal chain) are built on.
"""

from __future__ import annotations

import copy
import json
import os
import threading
import time
import uuid
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Iterable, Optional


class EventType(str, Enum):
    ADDED = "ADDED"
    MODIFIED = "MODIFIED"
    DELETED = "DELETED"


class ConflictError(Exception):
    """Optimistic-concurrency failure: resource_version moved underneath us."""


class AlreadyExistsError(Exception):
    pass


class NotFoundError(Exception):
    pass


@dataclass(frozen=True)
class OwnerRef:
    kind: str
    name: str


@dataclass
class Resource:
    """A single stored object.  ``spec`` is desired state, ``status`` observed.

    ``generation`` increments on every spec change (used by the platform's
    generation-aware create-or-replace, paper §6.3); ``resource_version`` is
    the store-global monotonic version of the last write to this object.
    """

    kind: str
    name: str
    namespace: str = "default"
    spec: dict = field(default_factory=dict)
    status: dict = field(default_factory=dict)
    labels: dict = field(default_factory=dict)
    owner_refs: tuple = ()
    uid: str = ""
    resource_version: int = 0
    generation: int = 1

    @property
    def key(self) -> tuple:
        return (self.kind, self.namespace, self.name)

    def clone(self) -> "Resource":
        return copy.deepcopy(self)

    def to_json(self) -> dict:
        d = {
            "kind": self.kind,
            "name": self.name,
            "namespace": self.namespace,
            "spec": self.spec,
            "status": self.status,
            "labels": self.labels,
            "owner_refs": [[o.kind, o.name] for o in self.owner_refs],
            "uid": self.uid,
            "resource_version": self.resource_version,
            "generation": self.generation,
        }
        return d

    @staticmethod
    def from_json(d: dict) -> "Resource":
        return Resource(
            kind=d["kind"],
            name=d["name"],
            namespace=d.get("namespace", "default"),
            spec=d.get("spec", {}),
            status=d.get("status", {}),
            labels=d.get("labels", {}),
            owner_refs=tuple(OwnerRef(k, n) for k, n in d.get("owner_refs", [])),
            uid=d.get("uid", ""),
            resource_version=d.get("resource_version", 0),
            generation=d.get("generation", 1),
        )


@dataclass(frozen=True)
class Event:
    seq: int
    type: EventType
    resource: Resource  # snapshot *after* the change (before, for DELETED)
    old: Optional[Resource] = None  # snapshot before a MODIFIED


def _match_labels(labels: dict, selector: dict) -> bool:
    return all(labels.get(k) == v for k, v in selector.items())


class Subscription:
    """A watch channel: replayed history followed by live events.

    Deliveries are queued; a runtime drains the queue.  Queues make event
    delivery *asynchronous* (as in Kubernetes) while the log's global ``seq``
    keeps it *totally ordered* — the property the paper's determinism argument
    (§4.4) relies on.
    """

    def __init__(self, kinds: Optional[tuple], namespace: Optional[str]):
        self.kinds = kinds
        self.namespace = namespace
        self._queue: list[Event] = []
        self._cond = threading.Condition()
        self.closed = False

    def _offer(self, event: Event) -> None:
        if self.kinds is not None and event.resource.kind not in self.kinds:
            return
        if self.namespace is not None and event.resource.namespace != self.namespace:
            return
        with self._cond:
            self._queue.append(event)
            self._cond.notify_all()

    def poll(self) -> Optional[Event]:
        with self._cond:
            if self._queue:
                return self._queue.pop(0)
            return None

    def take(self, timeout: Optional[float] = None) -> Optional[Event]:
        with self._cond:
            if not self._queue:
                self._cond.wait(timeout=timeout)
            if self._queue:
                return self._queue.pop(0)
            return None

    def __len__(self) -> int:
        with self._cond:
            return len(self._queue)

    def close(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()


class ResourceStore:
    """Thread-safe versioned object store with a total-order event log."""

    def __init__(self, wal_path: Optional[str] = None):
        self._lock = threading.RLock()
        self._objects: dict[tuple, Resource] = {}
        self._log: list[Event] = []
        self._seq = 0
        self._subs: list[Subscription] = []
        self._wal_path = wal_path
        self._wal_file = None
        if wal_path:
            self._wal_file = open(wal_path, "a", encoding="utf-8")

    # ------------------------------------------------------------------ CRUD

    def create(self, res: Resource) -> Resource:
        with self._lock:
            if res.key in self._objects:
                raise AlreadyExistsError(f"{res.key} already exists")
            stored = res.clone()
            self._seq += 1
            stored.resource_version = self._seq
            stored.generation = 1
            stored.uid = stored.uid or uuid.uuid4().hex[:12]
            self._objects[stored.key] = stored
            self._emit(Event(self._seq, EventType.ADDED, stored.clone()))
            return stored.clone()

    def get(self, kind: str, name: str, namespace: str = "default") -> Resource:
        with self._lock:
            key = (kind, namespace, name)
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            return self._objects[key].clone()

    def try_get(self, kind: str, name: str, namespace: str = "default") -> Optional[Resource]:
        try:
            return self.get(kind, name, namespace)
        except NotFoundError:
            return None

    def exists(self, kind: str, name: str, namespace: str = "default") -> bool:
        with self._lock:
            return (kind, namespace, name) in self._objects

    def list(
        self,
        kind: Optional[str] = None,
        namespace: Optional[str] = None,
        label_selector: Optional[dict] = None,
    ) -> list[Resource]:
        with self._lock:
            out = []
            for res in self._objects.values():
                if kind is not None and res.kind != kind:
                    continue
                if namespace is not None and res.namespace != namespace:
                    continue
                if label_selector and not _match_labels(res.labels, label_selector):
                    continue
                out.append(res.clone())
            return sorted(out, key=lambda r: r.key)

    def replace(self, res: Resource, expected_version: Optional[int] = None) -> Resource:
        """Compare-and-swap replace.  Spec changes bump ``generation``."""
        with self._lock:
            key = res.key
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            current = self._objects[key]
            if expected_version is not None and current.resource_version != expected_version:
                raise ConflictError(
                    f"{key}: expected v{expected_version}, store has v{current.resource_version}"
                )
            old = current.clone()
            stored = res.clone()
            stored.uid = current.uid
            self._seq += 1
            stored.resource_version = self._seq
            stored.generation = current.generation + (1 if stored.spec != current.spec else 0)
            self._objects[key] = stored
            self._emit(Event(self._seq, EventType.MODIFIED, stored.clone(), old=old))
            return stored.clone()

    def update(
        self,
        kind: str,
        name: str,
        mutate: Callable[[Resource], None],
        namespace: str = "default",
        retries: int = 16,
    ) -> Resource:
        """Read-modify-write with CAS retry.  ``mutate`` edits in place."""
        for _ in range(retries):
            cur = self.get(kind, name, namespace)
            ver = cur.resource_version
            mutate(cur)
            try:
                return self.replace(cur, expected_version=ver)
            except ConflictError:
                continue
        raise ConflictError(f"update of {(kind, namespace, name)} exhausted retries")

    def update_status(
        self, kind: str, name: str, patch: dict, namespace: str = "default"
    ) -> Resource:
        def mutate(res: Resource) -> None:
            res.status.update(patch)

        return self.update(kind, name, mutate, namespace=namespace)

    def delete(self, kind: str, name: str, namespace: str = "default") -> Resource:
        with self._lock:
            key = (kind, namespace, name)
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            res = self._objects.pop(key)
            self._seq += 1
            snap = res.clone()
            snap.resource_version = self._seq
            self._emit(Event(self._seq, EventType.DELETED, snap))
            return snap

    def try_delete(self, kind: str, name: str, namespace: str = "default") -> bool:
        try:
            self.delete(kind, name, namespace)
            return True
        except NotFoundError:
            return False

    def delete_collection(
        self,
        kind: Optional[str] = None,
        namespace: Optional[str] = None,
        label_selector: Optional[dict] = None,
    ) -> int:
        """Bulk deletion by label — the paper's §8 mitigation for slow GC.

        One pass, one lock acquisition, minimal per-object API cost.
        """
        with self._lock:
            targets = self.list(kind=kind, namespace=namespace, label_selector=label_selector)
            for res in targets:
                self.delete(res.kind, res.name, res.namespace)
            return len(targets)

    # ------------------------------------------------------- garbage collect

    def gc_collect(self) -> int:
        """Owner-reference garbage collection (the slow path the paper measured).

        Deletes objects whose *every* owner is gone.  Iterates to a fixed
        point, which is exactly the behaviour that scales poorly with the
        number of resources (paper §8, Fig. 7c) — kept faithful so the
        benchmark can reproduce the comparison against bulk deletion.
        """
        removed = 0
        while True:
            with self._lock:
                orphans = []
                for res in self._objects.values():
                    if not res.owner_refs:
                        continue
                    owners_alive = any(
                        (o.kind, res.namespace, o.name) in self._objects for o in res.owner_refs
                    )
                    if not owners_alive:
                        orphans.append(res.key)
            if not orphans:
                return removed
            for kind, namespace, name in orphans:
                try:
                    self.delete(kind, name, namespace)
                    removed += 1
                except NotFoundError:
                    pass

    # ------------------------------------------------------------- watching

    def watch(
        self,
        kinds: Optional[Iterable[str]] = None,
        namespace: Optional[str] = None,
        replay: bool = True,
    ) -> Subscription:
        """Subscribe to events.  With ``replay``, the subscriber first receives
        the full history — how restarted actors catch up (paper §5.3)."""
        sub = Subscription(tuple(kinds) if kinds is not None else None, namespace)
        with self._lock:
            if replay:
                for ev in self._log:
                    sub._offer(ev)
            self._subs.append(sub)
        return sub

    def unwatch(self, sub: Subscription) -> None:
        with self._lock:
            if sub in self._subs:
                self._subs.remove(sub)
            sub.close()

    def _emit(self, event: Event) -> None:
        self._log.append(event)
        if self._wal_file is not None:
            rec = {
                "seq": event.seq,
                "type": event.type.value,
                "resource": event.resource.to_json(),
            }
            self._wal_file.write(json.dumps(rec) + "\n")
            self._wal_file.flush()
            os.fsync(self._wal_file.fileno())
        for sub in self._subs:
            if not sub.closed:
                sub._offer(event)

    # ------------------------------------------------------------ durability

    @property
    def seq(self) -> int:
        with self._lock:
            return self._seq

    @property
    def event_log(self) -> list[Event]:
        with self._lock:
            return list(self._log)

    def close(self) -> None:
        with self._lock:
            for sub in self._subs:
                sub.close()
            self._subs.clear()
            if self._wal_file is not None:
                self._wal_file.close()
                self._wal_file = None

    @staticmethod
    def recover(wal_path: str) -> "ResourceStore":
        """Rebuild a store by replaying its write-ahead log (etcd restart)."""
        store = ResourceStore()
        if not os.path.exists(wal_path):
            store._wal_path = wal_path
            store._wal_file = open(wal_path, "a", encoding="utf-8")
            return store
        with open(wal_path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                res = Resource.from_json(rec["resource"])
                etype = EventType(rec["type"])
                store._seq = rec["seq"]
                if etype == EventType.DELETED:
                    store._objects.pop(res.key, None)
                else:
                    store._objects[res.key] = res
                store._log.append(Event(rec["seq"], etype, res))
        store._wal_path = wal_path
        store._wal_file = open(wal_path, "a", encoding="utf-8")
        return store


def wait_for(
    predicate: Callable[[], bool], timeout: float = 30.0, interval: float = 0.002
) -> bool:
    """Test/benchmark helper: spin until ``predicate()`` or timeout."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return predicate()
