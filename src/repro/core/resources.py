"""Versioned resource store — the framework's 'kube-api-server + etcd'.

The paper's architecture rests on Kubernetes providing *state-as-a-service*:
persistent, versioned objects with reliable, totally-ordered change
notifications (paper §3.3, §7.4).  This module provides that substrate:

- ``Resource``: a named, versioned object with ``spec`` (desired state) and
  ``status`` (observed state), labels, owner references, ``finalizers`` and
  a ``deletion_timestamp`` (Kubernetes two-phase deletion), and status
  ``conditions`` (typed observations with an ``observedGeneration``).
- ``ResourceStore``: thread-safe CRUD with optimistic concurrency
  (compare-and-swap on ``resource_version``), a total-order event log,
  watch subscriptions with full-history replay (what lets the instance
  operator recover by catching up — paper §5.3), label selectors,
  declarative mutation verbs (``apply`` create-or-replace with spec merge,
  ``patch``/``patch_status``), two-phase deletion (a finalized object is
  only *marked* deleted; it is reaped when the last finalizer goes),
  foreground cascade deletion driven by owner-reference finalizers,
  owner-reference garbage collection (and the paper's §8 mitigation:
  bulk deletion by label), watch-based condition waits (no spin-polling),
  and an optional write-ahead log for durability.

Nothing in here knows about streams, jobs, or JAX: it is the generic
substrate the cloud-native patterns (controller / conductor / coordinator /
causal chain) are built on.
"""

from __future__ import annotations

import copy
import json
import os
import threading
import time
import uuid
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Iterable, Optional


class EventType(str, Enum):
    ADDED = "ADDED"
    MODIFIED = "MODIFIED"
    DELETED = "DELETED"


class ConflictError(Exception):
    """Optimistic-concurrency failure: resource_version moved underneath us."""


class TerminatingError(ConflictError):
    """Invalid write against a terminating object (e.g. adding a finalizer
    after deletion was requested) — retrying cannot fix it."""


class AlreadyExistsError(Exception):
    pass


class NotFoundError(Exception):
    pass


@dataclass(frozen=True)
class OwnerRef:
    kind: str
    name: str


#: Store-managed finalizer implementing foreground cascade deletion: while it
#: is present the owner waits for every dependent to be reaped first.
FOREGROUND_FINALIZER = "store/foreground-deletion"


@dataclass
class Resource:
    """A single stored object.  ``spec`` is desired state, ``status`` observed.

    ``generation`` increments on every spec change (used by the platform's
    generation-aware create-or-replace, paper §6.3); ``resource_version`` is
    the store-global monotonic version of the last write to this object.

    Life cycle (Kubernetes semantics):

    - ``finalizers`` — opaque tokens actors place on an object they need to
      act on *before* it may disappear (e.g. drain a PE's input rings).
    - ``deletion_timestamp`` — ``delete`` on a finalized object only stamps
      this (the object is *terminating*); the store reaps it when the last
      finalizer is removed.  ``None`` means live.
    - ``status["conditions"]`` — list of ``{type, status, reason, message,
      observedGeneration, lastTransitionTime}`` observations (see
      ``set_condition``/``get_condition``).  ``observedGeneration`` records
      which spec generation the writer had seen, so readers can tell a stale
      condition from a current one.
    """

    kind: str
    name: str
    namespace: str = "default"
    spec: dict = field(default_factory=dict)
    status: dict = field(default_factory=dict)
    labels: dict = field(default_factory=dict)
    owner_refs: tuple = ()
    uid: str = ""
    resource_version: int = 0
    generation: int = 1
    finalizers: list = field(default_factory=list)
    deletion_timestamp: Optional[float] = None

    @property
    def key(self) -> tuple:
        return (self.kind, self.namespace, self.name)

    @property
    def terminating(self) -> bool:
        return self.deletion_timestamp is not None

    def clone(self) -> "Resource":
        return copy.deepcopy(self)

    def to_json(self) -> dict:
        d = {
            "kind": self.kind,
            "name": self.name,
            "namespace": self.namespace,
            "spec": self.spec,
            "status": self.status,
            "labels": self.labels,
            "owner_refs": [[o.kind, o.name] for o in self.owner_refs],
            "uid": self.uid,
            "resource_version": self.resource_version,
            "generation": self.generation,
            "finalizers": list(self.finalizers),
            "deletion_timestamp": self.deletion_timestamp,
        }
        return d

    @staticmethod
    def from_json(d: dict) -> "Resource":
        return Resource(
            kind=d["kind"],
            name=d["name"],
            namespace=d.get("namespace", "default"),
            spec=d.get("spec", {}),
            status=d.get("status", {}),
            labels=d.get("labels", {}),
            owner_refs=tuple(OwnerRef(k, n) for k, n in d.get("owner_refs", [])),
            uid=d.get("uid", ""),
            resource_version=d.get("resource_version", 0),
            generation=d.get("generation", 1),
            finalizers=list(d.get("finalizers", ())),
            deletion_timestamp=d.get("deletion_timestamp"),
        )


# ------------------------------------------------------------- conditions


def get_condition(res: Resource, cond_type: str) -> Optional[dict]:
    """The condition entry of ``cond_type`` on ``res``, or None."""
    for cond in res.status.get("conditions", ()):
        if cond.get("type") == cond_type:
            return cond
    return None


def condition_is(res: Resource, cond_type: str, status: str = "True",
                 min_generation: Optional[int] = None) -> bool:
    """True iff the condition exists with the wanted status string (and, when
    ``min_generation`` is given, was observed at that spec generation or
    later — the staleness guard)."""
    cond = get_condition(res, cond_type)
    if cond is None or cond.get("status") != status:
        return False
    if min_generation is not None and \
            cond.get("observedGeneration", 0) < min_generation:
        return False
    return True


def set_condition(res: Resource, cond_type: str, status: str,
                  reason: str = "", message: str = "",
                  observed_generation: Optional[int] = None,
                  now: Optional[float] = None) -> bool:
    """Upsert a condition on ``res`` in place (use inside a coordinator
    command or ``update`` mutate).  ``lastTransitionTime`` moves only when
    the status string actually changes (Kubernetes semantics);
    ``observedGeneration`` defaults to the resource's current generation.
    Returns True iff anything changed."""
    conds = res.status.setdefault("conditions", [])
    gen = res.generation if observed_generation is None else observed_generation
    entry = {"type": cond_type, "status": status, "reason": reason,
             "message": message, "observedGeneration": gen}
    for i, cond in enumerate(conds):
        if cond.get("type") != cond_type:
            continue
        entry["lastTransitionTime"] = (
            cond.get("lastTransitionTime", 0.0)
            if cond.get("status") == status
            else (time.time() if now is None else now))
        if all(cond.get(k) == v for k, v in entry.items()):
            return False
        conds[i] = entry
        return True
    entry["lastTransitionTime"] = time.time() if now is None else now
    conds.append(entry)
    return True


@dataclass(frozen=True)
class Event:
    seq: int
    type: EventType
    resource: Resource  # snapshot *after* the change (before, for DELETED)
    old: Optional[Resource] = None  # snapshot before a MODIFIED


def _match_labels(labels: dict, selector: dict) -> bool:
    return all(labels.get(k) == v for k, v in selector.items())


class Subscription:
    """A watch channel: replayed history followed by live events.

    Deliveries are queued; a runtime drains the queue.  Queues make event
    delivery *asynchronous* (as in Kubernetes) while the log's global ``seq``
    keeps it *totally ordered* — the property the paper's determinism argument
    (§4.4) relies on.
    """

    def __init__(self, kinds: Optional[tuple], namespace: Optional[str]):
        self.kinds = kinds
        self.namespace = namespace
        # deque: O(1) take from the head on the hot watch path (a plain
        # list's pop(0) is O(n) and this queue can hold a full replay)
        self._queue: deque[Event] = deque()
        self._cond = threading.Condition()
        self.closed = False

    def _offer(self, event: Event) -> None:
        if self.kinds is not None and event.resource.kind not in self.kinds:
            return
        if self.namespace is not None and event.resource.namespace != self.namespace:
            return
        with self._cond:
            self._queue.append(event)
            self._cond.notify_all()

    def head_seq(self) -> Optional[int]:
        """Global sequence number of the next event, or None when empty
        (the manual Runtime's canonical-schedule introspection)."""
        with self._cond:
            return self._queue[0].seq if self._queue else None

    def poll(self) -> Optional[Event]:
        with self._cond:
            if self._queue:
                return self._queue.popleft()
            return None

    def take(self, timeout: Optional[float] = None) -> Optional[Event]:
        with self._cond:
            if not self._queue:
                self._cond.wait(timeout=timeout)
            if self._queue:
                return self._queue.popleft()
            return None

    def __len__(self) -> int:
        with self._cond:
            return len(self._queue)

    def close(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()


class ResourceStore:
    """Thread-safe versioned object store with a total-order event log.

    Deletion is two-phase (Kubernetes semantics): ``delete`` on an object
    that carries finalizers only stamps ``deletion_timestamp`` and emits
    MODIFIED; the object is *reaped* (removed + DELETED emitted) when the
    last finalizer is removed.  ``delete(..., propagation="foreground")``
    additionally places the ``FOREGROUND_FINALIZER`` on the object and
    cascades the delete through its owner-reference dependents, reaping the
    owner only after the last dependent is gone — the happy-path
    replacement for the ``gc_collect`` fixed-point walk (paper §8).
    """

    def __init__(self, wal_path: Optional[str] = None):
        self._lock = threading.RLock()
        self._objects: dict[tuple, Resource] = {}
        # owner key -> {dependent keys}: keeps the foreground cascade's
        # per-reap dependent checks O(dependents), not O(store)
        self._deps: dict[tuple, set] = {}
        # foreground completion worklist (drained iteratively so ownership
        # chains deeper than the Python stack still cascade)
        self._fg_pending: deque = deque()
        self._fg_active = False
        self._log: list[Event] = []
        self._seq = 0
        self._subs: list[Subscription] = []
        self._wal_path = wal_path
        self._wal_file = None
        self.gc_runs = 0  # gc_collect invocations (tests assert the happy
        # path never needs the fixed-point walk)
        if wal_path:
            self._wal_file = open(wal_path, "a", encoding="utf-8")

    # ------------------------------------------------------------------ CRUD

    def create(self, res: Resource) -> Resource:
        with self._lock:
            if res.key in self._objects:
                raise AlreadyExistsError(f"{res.key} already exists")
            for owner in res.owner_refs:
                cur = self._objects.get((owner.kind, res.namespace, owner.name))
                if cur is not None and cur.terminating:
                    # a dependent created under a terminating owner would
                    # never be revisited by the cascade — refuse it
                    raise ConflictError(
                        f"owner {owner.kind}/{owner.name} is terminating")
            stored = res.clone()
            stored.deletion_timestamp = None
            self._seq += 1
            stored.resource_version = self._seq
            stored.generation = 1
            stored.uid = stored.uid or uuid.uuid4().hex[:12]
            self._objects[stored.key] = stored
            self._index_owners(stored)
            self._emit(Event(self._seq, EventType.ADDED, stored.clone()))
            return stored.clone()

    def _index_owners(self, res: Resource) -> None:
        for owner in res.owner_refs:
            self._deps.setdefault((owner.kind, res.namespace, owner.name),
                                  set()).add(res.key)

    def _unindex_owners(self, res: Resource) -> None:
        for owner in res.owner_refs:
            key = (owner.kind, res.namespace, owner.name)
            deps = self._deps.get(key)
            if deps is not None:
                deps.discard(res.key)
                if not deps:
                    del self._deps[key]

    def get(self, kind: str, name: str, namespace: str = "default") -> Resource:
        with self._lock:
            key = (kind, namespace, name)
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            return self._objects[key].clone()

    def try_get(self, kind: str, name: str, namespace: str = "default") -> Optional[Resource]:
        try:
            return self.get(kind, name, namespace)
        except NotFoundError:
            return None

    def exists(self, kind: str, name: str, namespace: str = "default") -> bool:
        with self._lock:
            return (kind, namespace, name) in self._objects

    def list(
        self,
        kind: Optional[str] = None,
        namespace: Optional[str] = None,
        label_selector: Optional[dict] = None,
    ) -> list[Resource]:
        with self._lock:
            out = []
            for res in self._objects.values():
                if kind is not None and res.kind != kind:
                    continue
                if namespace is not None and res.namespace != namespace:
                    continue
                if label_selector and not _match_labels(res.labels, label_selector):
                    continue
                out.append(res.clone())
            return sorted(out, key=lambda r: r.key)

    def replace(self, res: Resource, expected_version: Optional[int] = None) -> Resource:
        """Compare-and-swap replace.  Spec changes bump ``generation``.

        Two-phase-deletion bookkeeping: ``deletion_timestamp`` is store-owned
        (only ``delete`` sets it — a stale writer cannot resurrect a
        terminating object); adding finalizers to a terminating object is
        refused (it could postpone the reap forever); and removing the last
        finalizer from a terminating object reaps it.
        """
        with self._lock:
            key = res.key
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            current = self._objects[key]
            if expected_version is not None and current.resource_version != expected_version:
                raise ConflictError(
                    f"{key}: expected v{expected_version}, store has v{current.resource_version}"
                )
            if current.terminating and \
                    set(res.finalizers) - set(current.finalizers):
                raise TerminatingError(f"{key} is terminating; finalizers "
                                       "can only be removed")
            if (res.spec == current.spec and res.status == current.status
                    and res.labels == current.labels
                    and res.owner_refs == current.owner_refs
                    and res.finalizers == current.finalizers):
                # no-op write: don't bump the version or wake every watcher
                # (the idempotent lifecycle verbs — remove_finalizer of an
                # absent finalizer, re-set of an unchanged condition —
                # would otherwise re-enter the controllers that issued them)
                return current.clone()
            old = current.clone()
            stored = res.clone()
            stored.uid = current.uid
            stored.deletion_timestamp = current.deletion_timestamp
            self._seq += 1
            stored.resource_version = self._seq
            stored.generation = current.generation + (1 if stored.spec != current.spec else 0)
            if stored.owner_refs != current.owner_refs:
                self._unindex_owners(current)
                self._index_owners(stored)
            self._objects[key] = stored
            self._emit(Event(self._seq, EventType.MODIFIED, stored.clone(), old=old))
            out = stored.clone()
            if stored.terminating and not stored.finalizers:
                self._reap(stored)
            elif stored.terminating and \
                    FOREGROUND_FINALIZER in stored.finalizers and \
                    stored.finalizers != old.finalizers:
                # another finalizer just cleared: the foreground hold may be
                # the only thing left — re-run its dependent check
                self._schedule_foreground_check(stored.key)
            return out

    def update(
        self,
        kind: str,
        name: str,
        mutate: Callable[[Resource], None],
        namespace: str = "default",
        retries: int = 16,
    ) -> Resource:
        """Read-modify-write with CAS retry.  ``mutate`` edits in place."""
        for _ in range(retries):
            cur = self.get(kind, name, namespace)
            ver = cur.resource_version
            mutate(cur)
            try:
                return self.replace(cur, expected_version=ver)
            except TerminatingError:
                raise  # not a CAS race; retrying cannot make it valid
            except ConflictError:
                continue
        raise ConflictError(f"update of {(kind, namespace, name)} exhausted retries")

    def update_status(
        self, kind: str, name: str, patch: dict, namespace: str = "default"
    ) -> Resource:
        def mutate(res: Resource) -> None:
            res.status.update(patch)

        return self.update(kind, name, mutate, namespace=namespace)

    # --------------------------------------------- declarative verbs (apply)

    def apply(self, res: Resource) -> Resource:
        """Create-or-replace with spec-merge semantics (server-side apply).

        Absent -> create.  Present -> merge ``res.spec`` into the stored
        spec (labels likewise), leave status and finalizers alone, and bump
        the generation iff the merged spec actually changed.  The verb every
        declarative caller uses instead of hand-rolled exists/create/update.
        """
        with self._lock:
            if res.key not in self._objects:
                return self.create(res)

            def merge(cur: Resource) -> None:
                cur.spec.update(copy.deepcopy(res.spec))
                cur.labels.update(copy.deepcopy(res.labels))
                if res.owner_refs:
                    cur.owner_refs = res.owner_refs

            return self.update(res.kind, res.name, merge,
                               namespace=res.namespace)

    def patch(self, kind: str, name: str, spec_patch: dict,
              namespace: str = "default") -> Resource:
        """Merge ``spec_patch`` into the object's spec (generation bumps iff
        it changed something)."""
        def mutate(res: Resource) -> None:
            res.spec.update(copy.deepcopy(spec_patch))

        return self.update(kind, name, mutate, namespace=namespace)

    def patch_status(self, kind: str, name: str, patch: dict,
                     namespace: str = "default") -> Resource:
        """Merge ``patch`` into the object's status (alias of
        ``update_status``, named for symmetry with ``patch``)."""
        return self.update_status(kind, name, patch, namespace=namespace)

    # ------------------------------------------------------------- finalizers

    def add_finalizer(self, kind: str, name: str, finalizer: str,
                      namespace: str = "default") -> Resource:
        def mutate(res: Resource) -> None:
            if finalizer not in res.finalizers:
                res.finalizers.append(finalizer)

        return self.update(kind, name, mutate, namespace=namespace)

    def remove_finalizer(self, kind: str, name: str, finalizer: str,
                         namespace: str = "default") -> Optional[Resource]:
        """Remove a finalizer; reaps the object if it was terminating and
        this was the last one.  Missing object/finalizer is a no-op."""
        def mutate(res: Resource) -> None:
            if finalizer in res.finalizers:
                res.finalizers.remove(finalizer)

        try:
            return self.update(kind, name, mutate, namespace=namespace)
        except NotFoundError:
            return None

    # -------------------------------------------------------------- deletion

    def delete(self, kind: str, name: str, namespace: str = "default",
               propagation: str = "orphan") -> Resource:
        """Delete an object — two-phase when it carries finalizers.

        - no finalizers: removed immediately, DELETED emitted (the seed
          behaviour, and still the K8s behaviour for unfinalized objects);
        - finalizers present: ``deletion_timestamp`` stamped, MODIFIED
          emitted; the object is reaped when the last finalizer goes.
          A second delete of a terminating object is a no-op.
        - ``propagation="foreground"``: the object additionally gets the
          ``FOREGROUND_FINALIZER`` and the delete cascades through its
          owner-reference dependents; the object reaps only after the last
          dependent is gone (paper §8's GC, without the fixed-point walk).
        """
        with self._lock:
            key = (kind, namespace, name)
            if key not in self._objects:
                raise NotFoundError(f"{key} not found")
            if propagation == "foreground":
                return self._delete_foreground(self._objects[key])
            return self._delete_one(self._objects[key])

    def _delete_one(self, res: Resource) -> Resource:
        """Two-phase-aware single-object delete (lock held)."""
        if res.finalizers:
            if not res.terminating:  # stamp once; re-deletes are no-ops
                old = res.clone()
                res.deletion_timestamp = time.time()
                self._seq += 1
                res.resource_version = self._seq
                self._emit(Event(self._seq, EventType.MODIFIED, res.clone(),
                                 old=old))
            return res.clone()
        return self._reap(res)

    def _reap(self, res: Resource) -> Resource:
        """Actually remove an object and emit DELETED (lock held), then let
        any foreground-terminating owner re-check its dependents."""
        if self._objects.get(res.key) is None:
            return res.clone()  # already reaped (cascade re-entry)
        self._objects.pop(res.key, None)
        self._unindex_owners(res)
        self._seq += 1
        snap = res.clone()
        snap.resource_version = self._seq
        self._emit(Event(self._seq, EventType.DELETED, snap))
        for owner in res.owner_refs:
            owner_res = self._objects.get(
                (owner.kind, res.namespace, owner.name))
            if owner_res is not None and owner_res.terminating and \
                    FOREGROUND_FINALIZER in owner_res.finalizers:
                self._schedule_foreground_check(owner_res.key)
        return snap

    def _dependents(self, res: Resource) -> list[Resource]:
        keys = self._deps.get(res.key, ())
        return [self._objects[k] for k in list(keys) if k in self._objects]

    def _delete_foreground(self, res: Resource) -> Resource:
        """Foreground cascade (lock held, iterative — ownership chains can
        be deeper than the Python stack): stamp every reachable dependent
        with the foreground finalizer, then run completion checks until the
        queue drains.  Dependents that carry their own finalizers (e.g. a
        draining PE) hold their branch open until those are removed."""
        snap = None
        frontier = deque([res.key])
        seen = set()
        while frontier:
            key = frontier.popleft()
            if key in seen:
                continue
            seen.add(key)
            cur = self._objects.get(key)
            if cur is None:
                continue
            if not cur.terminating:
                if not cur.finalizers and not self._deps.get(key):
                    # unfinalized leaf: one DELETED event, not three
                    reaped = self._reap(cur)
                    if key == res.key:
                        snap = reaped
                    continue
                old = cur.clone()
                if FOREGROUND_FINALIZER not in cur.finalizers:
                    cur.finalizers.append(FOREGROUND_FINALIZER)
                cur.deletion_timestamp = time.time()
                self._seq += 1
                cur.resource_version = self._seq
                self._emit(Event(self._seq, EventType.MODIFIED, cur.clone(),
                                 old=old))
            if key == res.key:
                snap = cur.clone()
            frontier.extend(self._deps.get(key, ()))
            self._schedule_foreground_check(key)
        return snap if snap is not None else res.clone()

    def _schedule_foreground_check(self, key: tuple) -> None:
        """Queue a foreground completion check.  The queue is drained by
        the outermost caller only (re-entrant calls just enqueue), so a
        reap chain of any depth uses constant stack."""
        self._fg_pending.append(key)
        if self._fg_active:
            return
        self._fg_active = True
        try:
            while self._fg_pending:
                obj = self._objects.get(self._fg_pending.popleft())
                if obj is not None:
                    self._maybe_finish_foreground(obj)
        finally:
            self._fg_active = False

    def _maybe_finish_foreground(self, res: Resource) -> None:
        """Owner bookkeeping (lock held): when a foreground-terminating
        object has no dependents left, its foreground finalizer is removed —
        reaping it if that was the last finalizer, which in turn re-checks
        *its* owners (the cascade completes bottom-up)."""
        if self._objects.get(res.key) is not res:
            return  # already reaped (a dependent's reap finished it first)
        if self._deps.get(res.key):  # O(1) emptiness check per reap
            return
        if not res.terminating:
            return
        if FOREGROUND_FINALIZER in res.finalizers:
            old = res.clone()
            res.finalizers.remove(FOREGROUND_FINALIZER)
            self._seq += 1
            res.resource_version = self._seq
            self._emit(Event(self._seq, EventType.MODIFIED, res.clone(),
                             old=old))
        if not res.finalizers:
            self._reap(res)

    def try_delete(self, kind: str, name: str, namespace: str = "default",
                   propagation: str = "orphan") -> bool:
        try:
            self.delete(kind, name, namespace, propagation=propagation)
            return True
        except NotFoundError:
            return False

    def delete_collection(
        self,
        kind: Optional[str] = None,
        namespace: Optional[str] = None,
        label_selector: Optional[dict] = None,
    ) -> int:
        """Bulk deletion by label — the paper's §8 mitigation for slow GC.

        One pass, one lock acquisition, minimal per-object API cost.
        """
        with self._lock:
            targets = self.list(kind=kind, namespace=namespace, label_selector=label_selector)
            for res in targets:
                self.delete(res.kind, res.name, res.namespace)
            return len(targets)

    # ------------------------------------------------------- garbage collect

    def gc_collect(self) -> int:
        """Owner-reference garbage collection (the slow path the paper measured).

        Deletes objects whose *every* owner is gone.  Iterates to a fixed
        point, which is exactly the behaviour that scales poorly with the
        number of resources (paper §8, Fig. 7c) — kept faithful so the
        benchmark can reproduce the comparison against bulk deletion.
        """
        self.gc_runs += 1
        removed = 0
        while True:
            with self._lock:
                orphans = []
                for res in self._objects.values():
                    if not res.owner_refs or res.terminating:
                        # terminating orphans already await their finalizers;
                        # re-deleting them would spin this loop forever
                        continue
                    owners_alive = any(
                        (o.kind, res.namespace, o.name) in self._objects for o in res.owner_refs
                    )
                    if not owners_alive:
                        orphans.append(res.key)
            if not orphans:
                return removed
            for kind, namespace, name in orphans:
                try:
                    self.delete(kind, name, namespace)
                    removed += 1
                except NotFoundError:
                    pass

    # ------------------------------------------------------------- watching

    def watch(
        self,
        kinds: Optional[Iterable[str]] = None,
        namespace: Optional[str] = None,
        replay: bool = True,
    ) -> Subscription:
        """Subscribe to events.  With ``replay``, the subscriber first receives
        the full history — how restarted actors catch up (paper §5.3)."""
        sub = Subscription(tuple(kinds) if kinds is not None else None, namespace)
        with self._lock:
            if replay:
                for ev in self._log:
                    sub._offer(ev)
            self._subs.append(sub)
        return sub

    def unwatch(self, sub: Subscription) -> None:
        with self._lock:
            if sub in self._subs:
                self._subs.remove(sub)
            sub.close()

    # ------------------------------------------------------ watch-based waits

    def wait_resource(self, kind: str, name: str,
                      predicate: Callable[[Optional[Resource]], bool],
                      namespace: str = "default",
                      timeout: float = 30.0) -> bool:
        """Block until ``predicate(resource-or-None)`` holds, watching events
        instead of spin-polling (sub-interval sleeps cost ~10 ms of timer
        granularity each; a Condition wait costs nothing until woken).

        The predicate is evaluated on the current object first, then once per
        event touching the object (None for DELETED).  Returns False on
        timeout.
        """
        sub = self.watch(kinds=(kind,), namespace=namespace, replay=False)
        try:
            if predicate(self.try_get(kind, name, namespace)):
                return True
            deadline = time.monotonic() + timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return predicate(self.try_get(kind, name, namespace))
                ev = sub.take(timeout=remaining)
                if ev is None or ev.resource.name != name:
                    continue
                res = None if ev.type == EventType.DELETED else ev.resource
                if predicate(res):
                    return True
        finally:
            self.unwatch(sub)

    def wait_for_condition(self, kind: str, name: str, cond_type: str,
                           status: str = "True", namespace: str = "default",
                           timeout: float = 30.0,
                           min_generation: Optional[int] = None) -> bool:
        """Watch-based wait until the named object carries
        ``conditions[type].status == status`` (optionally at/after
        ``min_generation``).  No spin-polling."""
        return self.wait_resource(
            kind, name,
            lambda res: res is not None and condition_is(
                res, cond_type, status, min_generation=min_generation),
            namespace=namespace, timeout=timeout)

    def wait_deleted(self, kind: str, name: str, namespace: str = "default",
                     timeout: float = 30.0) -> bool:
        """Watch-based wait until the object is gone (reaped, not merely
        terminating)."""
        return self.wait_resource(kind, name, lambda res: res is None,
                                  namespace=namespace, timeout=timeout)

    def _emit(self, event: Event) -> None:
        self._log.append(event)
        if self._wal_file is not None:
            rec = {
                "seq": event.seq,
                "type": event.type.value,
                "resource": event.resource.to_json(),
            }
            self._wal_file.write(json.dumps(rec) + "\n")
            self._wal_file.flush()
            os.fsync(self._wal_file.fileno())
        for sub in self._subs:
            if not sub.closed:
                sub._offer(event)

    # ------------------------------------------------------------ durability

    @property
    def seq(self) -> int:
        with self._lock:
            return self._seq

    @property
    def event_log(self) -> list[Event]:
        with self._lock:
            return list(self._log)

    def close(self) -> None:
        with self._lock:
            for sub in self._subs:
                sub.close()
            self._subs.clear()
            if self._wal_file is not None:
                self._wal_file.close()
                self._wal_file = None

    @staticmethod
    def recover(wal_path: str) -> "ResourceStore":
        """Rebuild a store by replaying its write-ahead log (etcd restart)."""
        store = ResourceStore()
        if not os.path.exists(wal_path):
            store._wal_path = wal_path
            store._wal_file = open(wal_path, "a", encoding="utf-8")
            return store
        with open(wal_path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                res = Resource.from_json(rec["resource"])
                etype = EventType(rec["type"])
                store._seq = rec["seq"]
                if etype == EventType.DELETED:
                    store._objects.pop(res.key, None)
                else:
                    store._objects[res.key] = res
                store._log.append(Event(rec["seq"], etype, res))
        for res in store._objects.values():
            store._index_owners(res)  # rebuild the cascade's dependent index
        store._wal_path = wal_path
        store._wal_file = open(wal_path, "a", encoding="utf-8")
        # complete deletions the crash interrupted: a terminating object
        # whose finalizers are already gone reaps now, and every foreground
        # hold re-checks its dependents (a crash between a dependent's
        # DELETED record and the owner's finalizer-removal record would
        # otherwise leave the owner terminating forever — nothing else
        # re-triggers the check after a restart)
        for res in list(store._objects.values()):
            if res.terminating and not res.finalizers:
                store._reap(res)
        for res in list(store._objects.values()):
            if res.terminating and FOREGROUND_FINALIZER in res.finalizers:
                store._schedule_foreground_check(res.key)
        return store


def wait_for(
    predicate: Callable[[], bool], timeout: float = 30.0, interval: float = 0.002
) -> bool:
    """Test/benchmark helper: spin until ``predicate()`` or timeout."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return predicate()
