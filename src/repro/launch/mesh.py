"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:   (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for real (executing) multi-device tests on host devices."""
    return jax.make_mesh(shape, axes)


HW = {
    # TPU v5e-class roofline constants (per chip)
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw_per_link": 50e9,  # B/s per link
}
