"""Serving launcher: batched-request serving of any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \\
      --requests 8 --slots 4 --max-new 16

Uses the continuous-batching engine (prefill-by-decode admission, greedy
sampling).  ``--platform`` submits a serving Job through the cloud-native
control plane instead (replicated servers behind a router).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--platform", action="store_true")
    args = ap.parse_args()

    if args.platform:
        from ..platform import Platform

        arch = args.arch
        if args.smoke:
            from ..configs import reduced_config

            arch = reduced_config(args.arch)
        p = Platform(num_nodes=4)
        try:
            p.submit("serve", {"app": {"type": "serve", "arch": arch,
                                       "replicas": 2}})
            assert p.wait_submitted("serve", 60)
            assert p.wait_full_health("serve", 120)
            print("serving job healthy:",
                  [(x.spec["peId"], x.status.get("phase")) for x in p.pods("serve")])
            time.sleep(2)
        finally:
            p.delete_job("serve")
            p.wait_terminated("serve", 30)
            p.shutdown()
        return

    import jax

    from ..configs import get_config, reduced_config
    from ..models import ModelOptions, init_params
    from ..serve import Request, ServeEngine

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    opts = ModelOptions(compute_dtype="float32" if jax.default_backend() == "cpu"
                        else "bfloat16")
    print(f"loading {cfg.name}: {cfg.param_count()/1e6:.0f}M params")
    params = init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, num_slots=args.slots,
                         max_len=args.max_len, opts=opts)
    for rid in range(args.requests):
        prompt = [1 + rid % 13, 7, (rid * 31) % cfg.vocab_size]
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
