"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds:

  compute    = HLO_FLOPs          / (chips × peak_FLOP/s)
  memory     = HLO_bytes_accessed / (chips × HBM_bw)
  collective = collective_bytes   / (chips × link_bw)

FLOPs/bytes/collective-bytes come from ``repro.launch.hlo_analysis`` —
a trip-count-aware walk of the optimized (post-SPMD) HLO, because XLA's
``cost_analysis()`` counts scan bodies once (10-100x undercount).
"""

from __future__ import annotations

from .mesh import HW

def roofline_terms(flops_dev: float, bytes_dev: float,
                   collective_bytes_per_device: float, num_chips: int,
                   f32_upcast_correction: bool = True) -> dict:
    """Per-device-program totals (trip-count-aware, from hlo_analysis).

    The CPU dry-run backend upcasts bf16 dots/activations to f32; on the TPU
    target the data plane is bf16, so with ``f32_upcast_correction`` the
    memory and collective byte totals are halved to reflect target-dtype
    traffic (FLOPs are dtype-independent).  Both raw and corrected values
    are recorded.
    """
    corr = 0.5 if f32_upcast_correction else 1.0
    compute_s = flops_dev / HW["peak_flops_bf16"]
    memory_s = bytes_dev * corr / HW["hbm_bw"]
    collective_s = collective_bytes_per_device * corr / HW["ici_bw_per_link"]
    terms = {
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": float(collective_bytes_per_device),
        "f32_upcast_correction": corr,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dominant.replace("_s", "")
    # roofline fraction: how much of the step is useful compute if the
    # dominant term fully hides the others
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction_compute"] = compute_s / total if total else 0.0
    return terms


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N active params."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token each
