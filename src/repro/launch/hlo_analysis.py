"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits each computation ONCE —
a lax.scan over 18 layer groups contributes its body's FLOPs a single time,
undercounting scanned models by >10x.  The roofline needs per-*execution*
totals, so we parse the scheduled HLO module ourselves:

- a symbol table per computation resolves operand shapes (operands are
  printed without shapes in scheduled modules);
- ``dot`` FLOPs = 2 * |out| * |contracted lhs dims|, attributed through
  fusions;
- while loops multiply their body by the trip count from
  ``backend_config={"known_trip_count":{"n":...}}`` (XLA emits this for
  counted loops, i.e. every lax.scan), falling back to the loop-condition
  comparison constant;
- collective wire bytes = max(input, output) tuple-aware byte size, keyed by
  kind and replica-group size (group size 2 = the cross-pod axis on the
  (2,16,16) mesh — what gradient compression attacks);
- "bytes accessed" = operands+outputs of non-trivial ops at fusion
  boundaries (fusion internals live in registers/VMEM, not HBM).

All numbers are per device-program, per execution.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_HEAD_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(
    r"(body|condition|to_apply|calls|true_computation|false_computation|"
    r"branch_computations)=\{?%?([\w\.\-,%\s]+?)[},]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COLLECTIVES = {"all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                "collective-permute"}
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota", "token"}


def _shape_list_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str):
    """First shape's dims in ``text`` as a list of ints."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    var: str
    shape_str: str
    opcode: str
    operands: list
    attrs: str
    args: str = ""


@dataclass
class Comp:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # var -> shape_str


def parse_module(text: str) -> tuple:
    """Returns (comps: dict name -> Comp, entry_name)."""
    comps: dict[str, Comp] = {}
    entry = None
    cur: Comp | None = None
    for raw in text.splitlines():
        if cur is None:
            m = _HEAD_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = Comp(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if raw.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        var, rhs = m.group(1), m.group(2)
        sm = re.match(r"^(\(.*?\)|\S+)\s+([\w\-]+)\(", rhs)
        if not sm:
            continue
        shape_str, opcode = sm.group(1), sm.group(2)
        paren = rhs[sm.end() - 1:]
        depth = 0
        args = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        operands = re.findall(r"%([\w\.\-]+)", args)
        attrs = rhs[sm.end() - 1 + len(args) + 2:]
        cur.ops.append(Op(var, shape_str, opcode, operands, attrs, args))
        cur.shapes[var] = shape_str
    return comps, entry


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0  # TPU-fusion-modeled HBM traffic proxy
    bytes_raw: float = 0.0  # every op boundary (upper bound)
    coll_bytes: float = 0.0
    coll_by_key: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_raw += other.bytes_raw * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_key.items():
            self.coll_by_key[k] = self.coll_by_key.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


# Ops whose fusion-boundary bytes represent real HBM traffic on TPU.  The CPU
# backend wraps nearly every elementwise op in its own kLoop micro-fusion; a
# TPU module fuses those chains into neighbours, so counting every boundary
# would overstate HBM traffic ~5-10x.  We count a fusion's boundary iff it
# contains at least one op from this set (matmuls, reductions, data-movement
# that must round-trip memory).
_SIGNIFICANT = {"dot", "convolution", "reduce", "scatter", "gather",
                "dynamic-update-slice", "dynamic-slice", "sort",
                "reduce-window", "select-and-scatter"}


def _dot_flops(op: Op, comp: Comp) -> float:
    out_dims = _shape_dims(op.shape_str) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    lhs_shape = comp.shapes.get(op.operands[0]) if op.operands else None
    lhs_dims = _shape_dims(lhs_shape or "") or []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contracted = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    return 2.0 * out_n * contracted


def _conv_flops(op: Op, comp: Comp) -> float:
    # rough: 2 * |out| * prod(kernel dims) (no feature-group correction)
    out_dims = _shape_dims(op.shape_str) or []
    out_n = 1
    for d in out_dims:
        out_n *= d
    rhs_shape = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
    k = 1
    for d in (_shape_dims(rhs_shape or "") or [])[:-1]:
        k *= d
    return 2.0 * out_n * k


def _trip_count(op: Op, comps: dict) -> int | None:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest integer constant in the loop condition computation
    cm = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
    if cm and cm.group(1) in comps:
        best = None
        for cop in comps[cm.group(1)].ops:
            if cop.opcode == "constant":
                mc = re.match(r"(\d+)$", cop.args.strip())
                if mc:
                    best = max(best or 0, int(mc.group(1)))
        return best
    return None


_SLICING = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}


def _fusion_kind(comp_name: str, comps: dict, cache: dict) -> str:
    """'slicing' | 'significant' | 'trivial' for a fused computation."""
    if comp_name in cache:
        return cache[comp_name]
    kind = "trivial"
    comp = comps.get(comp_name)
    if comp is not None:
        ops = {op.opcode for op in comp.ops}
        if ops & _SLICING:
            kind = "slicing"
        elif ops & _SIGNIFICANT:
            kind = "significant"
    cache[comp_name] = kind
    return kind


def analyze(text: str) -> Totals:
    comps, entry = parse_module(text)
    memo: dict[str, Totals] = {}
    sig_cache: dict[str, bool] = {}

    def visit(name: str, stack: tuple) -> Totals:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Totals()
        comp = comps[name]
        t = Totals()
        for op in comp.ops:
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                out_b = _shape_list_bytes(op.shape_str)
                in_b = sum(_shape_list_bytes(comp.shapes.get(o, ""))
                           for o in op.operands)
                buf = max(out_b, in_b)
                gm = _GROUPS_RE.search(op.attrs)
                gsize = int(gm.group(2)) if gm else 0
                # ring-wire bytes per device: all-reduce moves 2N(g-1)/g
                # (reduce-scatter + all-gather phases); AG/RS/A2A move
                # N(g-1)/g; collective-permute moves N.
                frac = (gsize - 1) / gsize if gsize > 1 else 1.0
                if base == "all-reduce":
                    wire = 2.0 * buf * frac
                elif base == "collective-permute":
                    wire = float(buf)
                else:
                    wire = buf * frac
                key = f"{base}/g{gsize}"
                t.coll_bytes += wire
                t.coll_by_key[key] = t.coll_by_key.get(key, 0.0) + wire
                t.bytes += out_b + in_b
                t.bytes_raw += out_b + in_b
                continue
            if op.opcode == "dot":
                t.flops += _dot_flops(op, comp)
            elif op.opcode == "convolution":
                t.flops += _conv_flops(op, comp)
            if op.opcode not in _NO_BYTES_OPS and "-start" not in op.opcode:
                out_b = _shape_list_bytes(op.shape_str)
                in_b = sum(_shape_list_bytes(comp.shapes.get(o, ""))
                           for o in op.operands)
                t.bytes_raw += out_b + in_b
                # slicing ops touch only the slice region, not the full
                # operand (a DUS into a 32k-token cache writes one slot; a
                # scan's dynamic-slice reads one layer's params)
                if op.opcode in ("dynamic-slice", "gather"):
                    t.bytes += 2 * out_b
                elif op.opcode == "dynamic-update-slice":
                    upd = _shape_list_bytes(
                        comp.shapes.get(op.operands[1], "")) if len(op.operands) > 1 else 0
                    t.bytes += 3 * upd
                elif op.opcode == "scatter":
                    upd = _shape_list_bytes(
                        comp.shapes.get(op.operands[2], "")) if len(op.operands) > 2 else out_b
                    t.bytes += 3 * upd
                elif op.opcode == "fusion":
                    cm = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                    kind = _fusion_kind(cm.group(1), comps, sig_cache) if cm else "trivial"
                    if kind == "slicing":
                        # update-fusion: outputs alias the big buffers (the
                        # CPU backend fuses several DUS ops into one, so
                        # MULTIPLE operands are aliased buffers); traffic is
                        # the update slices = operands strictly smaller than
                        # the largest.  slice-read fusion: traffic = output.
                        ops_b = [_shape_list_bytes(comp.shapes.get(o, ""))
                                 for o in op.operands]
                        max_op = max(ops_b) if ops_b else 0
                        if out_b >= max_op and ops_b:  # dynamic-update-slice
                            small = sum(b for b in ops_b if b < max_op)
                            t.bytes += 3 * small
                        else:  # dynamic-slice / gather
                            t.bytes += 2 * out_b
                    elif kind == "significant":
                        t.bytes += out_b + in_b
                elif op.opcode in _SIGNIFICANT or op.opcode in (
                        "copy", "concatenate", "pad", "while"):
                    t.bytes += out_b + in_b
            # recursion
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                if bm:
                    trip = _trip_count(op, comps)
                    if trip is None:
                        trip = 1
                        t.unknown_trip_loops += 1
                    t.add(visit(bm.group(1), stack + (name,)), trip)
            elif op.opcode in ("fusion", "call", "conditional", "map"):
                for attr in ("calls", "to_apply", "true_computation",
                             "false_computation"):
                    for cm in re.finditer(rf"{attr}=%?([\w\.\-]+)", op.attrs):
                        sub = visit(cm.group(1), stack + (name,))
                        # fusion internals: count FLOPs & collectives, not bytes
                        t.flops += sub.flops
                        t.coll_bytes += sub.coll_bytes
                        for k, v in sub.coll_by_key.items():
                            t.coll_by_key[k] = t.coll_by_key.get(k, 0.0) + v
                        t.unknown_trip_loops += sub.unknown_trip_loops
                if op.opcode == "conditional":
                    bm = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
                    if bm:
                        for branch in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                            sub = visit(branch, stack + (name,))
                            t.flops += sub.flops
                            t.coll_bytes += sub.coll_bytes
        memo[name] = t
        return t

    if entry is None:
        return Totals()
    return visit(entry, ())
