import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for the
single-pod (16,16) and multi-pod (2,16,16) production meshes, every cell
must ``.lower().compile()`` cleanly; we record memory_analysis,
cost_analysis, and collective bytes (parsed from the optimized HLO) into a
JSON results file that EXPERIMENTS.md §Dry-run / §Roofline and the §Perf
hillclimb read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

The XLA_FLAGS line above must run before ANY jax import — jax locks the
device count on first init.  Do not import this module from code that has
already initialized jax with a different device count.
"""

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from .cells import CellOptions, build_cell, lower_cell, token_count
from .hlo_analysis import analyze
from .mesh import make_production_mesh
from .roofline import model_flops, roofline_terms


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             opts: CellOptions = CellOptions(), verbose: bool = True) -> dict:
    """Lower + compile one cell; return the full record (or skip/error)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.size
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, opts)
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        totals = analyze(hlo)
        terms = roofline_terms(totals.flops, totals.bytes, totals.coll_bytes,
                               num_chips)
        mf = model_flops(cfg, shape, cell.kind)
        terms["model_flops"] = mf
        hlo_total = terms["hlo_flops_per_device"] * num_chips
        terms["model_vs_hlo_flops"] = mf / hlo_total if hlo_total else 0.0
        terms["unknown_trip_loops"] = totals.unknown_trip_loops
        rec.update(
            status="ok",
            kind=cell.kind,
            tokens=token_count(cfg, shape),
            batch_axes=list(cell.meta["batch_axes"]),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            collectives=totals.coll_by_key,
            cost_analysis_raw={"flops": float(cost.get("flops", 0.0)),
                               "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
            roofline=terms,
        )
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name}: OK "
                  f"compile={t_compile:.0f}s "
                  f"compute={terms['compute_s']*1e3:.2f}ms "
                  f"memory={terms['memory_s']*1e3:.2f}ms "
                  f"collective={terms['collective_s']*1e3:.2f}ms "
                  f"dominant={terms['dominant']} "
                  f"useful={terms['model_vs_hlo_flops']:.2f}")
    except Exception as exc:  # noqa: BLE001 - record the failure, keep going
        rec.update(status="error", error=repr(exc),
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name}: FAIL {exc!r}")
    return rec


def all_cells(multi_pod_values=(False, True)):
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            for mp in multi_pod_values:
                yield arch, shape_name, mp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true", help="use the 2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--tree-attention", action="store_true")
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--shard-cache-seq", action="store_true")
    ap.add_argument("--moe-impl", default=None, choices=(None, "einsum", "sort"))
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--dp-layout", action="store_true")
    args = ap.parse_args()

    from ..models import ModelOptions
    from ..train.step import TrainConfig

    opts = CellOptions(
        model=ModelOptions(tree_attention=args.tree_attention,
                           moe_impl=args.moe_impl),
        train=TrainConfig(compress_pod_grads=args.compress_pod_grads),
        sequence_parallel=args.sequence_parallel,
        shard_cache_seq=args.shard_cache_seq,
        dp_layout=args.dp_layout,
    )

    records = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records
            if r.get("status") in ("ok", "skipped")}

    if args.all:
        meshes = (False, True) if args.both_meshes or not args.multipod else (True,)
        if args.both_meshes:
            meshes = (False, True)
        elif args.multipod:
            meshes = (True,)
        else:
            meshes = (False,)
        cells = list(all_cells(meshes))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multipod)]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    for arch, shape_name, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        if (arch, shape_name, mesh_name) in done:
            continue
        rec = run_cell(arch, shape_name, multi_pod=mp, opts=opts)
        records = [r for r in records if (r["arch"], r["shape"], r["mesh"])
                   != (rec["arch"], rec["shape"], rec["mesh"])]
        records.append(rec)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(1 for r in records if r.get("status") == "ok")
    n_skip = sum(1 for r in records if r.get("status") == "skipped")
    n_err = sum(1 for r in records if r.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
