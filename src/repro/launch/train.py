"""Training launcher: run a (possibly sharded) training job directly, or
submit it to the platform.

Direct mode executes real steps on the available devices — used with
XLA_FLAGS=--xla_force_host_platform_device_count=8 for multi-device CPU
runs, or on a real TPU slice with the production mesh:

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \\
      --steps 20 --batch 8 --seq 64
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \\
      --mesh 2,2,2 --steps 10

Platform mode (--platform) submits a Job CRD and drives it through the
cloud-native control plane (checkpointing, recovery, elasticity):

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \\
      --platform --steps 40
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default=None, help="e.g. 2,2,2 for pod,data,model")
    ap.add_argument("--platform", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    if args.platform:
        from ..platform import Platform

        arch = args.arch
        if args.smoke:
            from ..configs import reduced_config

            arch = reduced_config(args.arch)
        p = Platform(num_nodes=4)
        try:
            p.submit("train", {
                "app": {"type": "train", "arch": arch, "data_parallel": 2,
                        "steps": args.steps, "batch_per_shard": max(args.batch // 2, 1),
                        "seq_len": args.seq, "lr": args.lr},
                "consistentRegion": {"name": "dp",
                                     "interval": max(args.steps // 4, 1)},
            })
            assert p.wait_full_health("train", 120)
            last = -1
            while True:
                ms = p.metrics("train")
                steps = [m.get("step", 0) for m in ms.values()]
                if steps and max(steps) > last:
                    last = max(steps)
                    losses = [m["loss"] for m in ms.values() if "loss" in m]
                    print(f"step {last:4d} loss {min(losses):.4f}")
                if steps and max(steps) >= args.steps:
                    break
                time.sleep(0.5)
        finally:
            p.delete_job("train")
            p.wait_terminated("train", 30)
            p.shutdown()
        return

    import jax

    from ..configs import get_config, reduced_config
    from ..data import StreamSource
    from ..models import ModelOptions
    from ..sharding.ctx import activation_rules
    from ..train import (TrainConfig, batch_sharding, init_train_state,
                         make_train_step, train_state_specs)

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    opts = ModelOptions(compute_dtype="float32" if jax.default_backend() == "cpu"
                        else "bfloat16")
    tcfg = TrainConfig(accum_steps=args.accum, remat=not args.smoke)
    src = StreamSource(vocab_size=cfg.vocab_size, batch=args.batch,
                       seq_len=args.seq, seed=0)
    state = init_train_state(jax.random.key(0), cfg, tcfg)

    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        mesh = jax.make_mesh(shape, axes)
        specs = train_state_specs(state, mesh)
        state = jax.device_put(state, specs)
        bspecs = batch_sharding(mesh, src.batch_at(0))
        step = jax.jit(make_train_step(cfg, tcfg, opts, mesh=mesh,
                                       act_rules=activation_rules()),
                       in_shardings=(specs, bspecs), donate_argnums=0)
    else:
        bspecs = None
        step = jax.jit(make_train_step(cfg, tcfg, opts), donate_argnums=0)

    for i in range(args.steps):
        batch = src.batch_at(i)
        if bspecs is not None:
            batch = jax.device_put(batch, bspecs)
        t0 = time.time()
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        print(f"step {i:4d} loss {loss:9.4f} gnorm {float(metrics['grad_norm']):8.3f} "
              f"({time.time() - t0:.2f}s)")


if __name__ == "__main__":
    main()
