"""Cell construction: (arch × shape × mesh) -> a lowerable step function.

A *cell* is one entry of the dry-run/roofline matrix.  This module builds,
for any cell: the step function (train_step / prefill_step / serve_step),
abstract input stand-ins (ShapeDtypeStructs — never allocated), and the
in/out shardings, so both the dry-run and the benchmarks consume one code
path.  All placement is *computed* from (arch, shape, mesh, rules) — the
paper's deterministic-naming principle applied to distribution metadata.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ArchConfig, SHAPES, ShapeCfg, get_config, shape_applicable
from ..data.stream import batch_specs
from ..models import ModelOptions, abstract_params, decode_step, init_cache, stack_plan
from ..sharding.ctx import activation_rules, use_rules
from ..sharding.specs import PARAM_RULES, param_specs
from ..train.step import (
    TrainConfig,
    abstract_train_state,
    batch_sharding,
    make_train_step,
    train_state_specs,
)
from ..serve.engine import make_prefill_step


@dataclass(frozen=True)
class CellOptions:
    """Perf levers for a cell (the §Perf hillclimb mutates these)."""

    model: ModelOptions = ModelOptions()
    train: TrainConfig = TrainConfig()
    sequence_parallel: bool = False
    shard_cache_seq: bool = False
    # DP-dominant layout: batch shards over the model axis too and params
    # replicate — the right layout for small archs where TP collectives
    # dwarf per-device compute (xlstm/gemma-scale; §Perf)
    dp_layout: bool = False
    param_rules: dict = field(default_factory=lambda: dict(PARAM_RULES))


def data_axes_for(mesh: Mesh, global_batch: int,
                  include_model: bool = False) -> tuple:
    """Largest prefix of (pod, data[, model]) that divides the batch."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in names if a in mesh.axis_names]
    size = 1
    chosen = []
    for a in axes:
        n = mesh.shape[a]
        if global_batch % (size * n) == 0:
            chosen.append(a)
            size *= n
    return tuple(chosen)


def cache_specs(cache_abs, cfg: ArchConfig, mesh: Mesh, batch_axes: tuple,
                rules: dict):
    """NamedShardings for a decode cache pytree (derived from leaf shapes)."""
    B = cache_abs["len"].shape[0]
    model_ax = rules.get("kv_heads")
    cache_seq_ax = rules.get("cache_seq")
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    num_heads = cfg.num_heads

    model_size = mesh.shape[model_ax] if model_ax else 1

    def spec_for(x):
        shape = x.shape
        # strip the stacked main-group leading dim: (groups, B, ...)
        lead = ()
        if len(shape) >= 2 and shape[0] != B and shape[1] == B:
            lead = (None,)
            shape = shape[1:]
        if not shape or shape[0] != B:
            return P()
        rest = shape[1:]
        if len(rest) == 3 and rest[-2:] == (kv, hd):  # (B, S, KV, hd) kv cache
            seq_ax = cache_seq_ax
            kv_ax = model_ax
            if kv % model_size != 0:
                # MQA/GQA kv heads don't divide the tensor axis: split-K over
                # the cache sequence instead (flash-decode style) so the cache
                # is never replicated across the tensor axis.
                kv_ax = None
                if rest[0] % model_size == 0:
                    seq_ax = model_ax
            return P(*lead, batch_axes, seq_ax, kv_ax, None)
        if len(rest) == 3 and rest[0] == num_heads:  # mLSTM C (B, H, dk, dv)
            return P(*lead, batch_axes, model_ax, None, None)
        if len(rest) == 2 and rest[0] == num_heads:  # (B, H, dk)
            return P(*lead, batch_axes, model_ax, None)
        if len(rest) == 2:  # conv state (B, W-1, C)
            return P(*lead, batch_axes, None, model_ax)
        if len(rest) == 1 and rest[0] == num_heads:  # (B, H)
            return P(*lead, batch_axes, model_ax)
        if len(rest) == 1:  # (B, d) recurrent channels
            return P(*lead, batch_axes, model_ax)
        return P(*lead, batch_axes)

    from ..sharding.specs import fit_spec

    return jax.tree.map(
        lambda x: NamedSharding(mesh, fit_spec(spec_for(x), x.shape, mesh)),
        cache_abs)


@dataclass
class Cell:
    arch: str
    shape: ShapeCfg
    cfg: ArchConfig
    kind: str  # train | prefill | decode
    fn: object  # the step callable
    args: tuple  # abstract args
    in_shardings: tuple
    donate_argnums: tuple
    meta: dict


def token_count(cfg: ArchConfig, shape: ShapeCfg) -> int:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               opts: CellOptions = CellOptions()) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {why}")

    batch_axes = data_axes_for(mesh, shape.global_batch,
                               include_model=opts.dp_layout)
    act_rules = activation_rules(
        data_axes=batch_axes,
        sequence_parallel=opts.sequence_parallel,
        shard_cache_seq=opts.shard_cache_seq,
    )
    if opts.dp_layout:
        # params replicated (grad all-reduce is the only collective); keep
        # tensor-axis names out of the activation rules as well
        act_rules = {k: (v if k in ("batch", "dp") else None)
                     for k, v in act_rules.items()}
        prules = {}
    else:
        prules = {k: v for k, v in opts.param_rules.items() if
                  (v in mesh.axis_names if isinstance(v, str) else True)}

    ftok = cfg.frontend_len if cfg.frontend else 0
    seq_tok = shape.seq_len - ftok

    if shape.kind == "train":
        tcfg = opts.train
        if tcfg.compress_pod_grads:
            tcfg = TrainConfig(optimizer=tcfg.optimizer, accum_steps=tcfg.accum_steps,
                               compress_pod_grads=True,
                               num_pods=mesh.shape.get("pod", 1), remat=tcfg.remat)
        state_abs = abstract_train_state(cfg, tcfg)
        st_specs = train_state_specs(state_abs, mesh, prules)
        batch = batch_specs(cfg.vocab_size, shape.global_batch, seq_tok,
                            ftok, cfg.frontend_dim)
        b_specs = batch_sharding(mesh, batch, batch_axes)
        step = make_train_step(cfg, tcfg, opts.model, mesh=mesh, act_rules=act_rules)
        return Cell(arch, shape, cfg, "train", step, (state_abs, batch),
                    (st_specs, b_specs), (0,), {"batch_axes": batch_axes})

    params_abs = abstract_params(cfg)
    p_specs = param_specs(params_abs, mesh, prules)

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, seq_tok), jnp.int32)}
        if ftok:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, ftok, cfg.frontend_dim), jnp.float32)
        b_specs = batch_sharding(mesh, batch, batch_axes)
        step = make_prefill_step(cfg, opts.model, max_len=shape.seq_len,
                                 mesh=mesh, act_rules=act_rules)
        return Cell(arch, shape, cfg, "prefill", step, (params_abs, batch),
                    (p_specs, b_specs), (), {"batch_axes": batch_axes})

    # decode: one new token against a cache of seq_len
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           dtype=opts.model.dtype))
    c_specs = cache_specs(cache_abs, cfg, mesh, batch_axes, act_rules)
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    t_spec = NamedSharding(mesh, P(batch_axes))

    def step(params, cache, toks):
        with use_rules(mesh, act_rules):
            return decode_step(params, cfg, cache, toks, opts.model)

    return Cell(arch, shape, cfg, "decode", step,
                (params_abs, cache_abs, tokens),
                (p_specs, c_specs, t_spec), (1,), {"batch_axes": batch_axes})


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums)
    return jitted.lower(*cell.args)
