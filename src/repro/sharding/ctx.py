"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names via ``shard(x,
axes)``; the launcher binds logical names to mesh axes with ``use_rules``.
Outside any binding (unit tests, single device) ``shard`` is the identity —
the models stay mesh-agnostic, mirroring the paper's split between
application code and platform-owned placement.

Rule sets are plain dicts: logical name -> mesh axis (str), tuple of mesh
axes, or None.  Unknown names shard to None (replicated).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> Optional[tuple]:
    return getattr(_state, "binding", None)


@contextmanager
def use_rules(mesh: Mesh, rules: dict):
    prev = _current()
    _state.binding = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.binding = prev


def resolve(axes: tuple, rules: dict) -> P:
    parts = []
    for a in axes:
        if a is None:
            parts.append(None)
        else:
            parts.append(rules.get(a))
    return P(*parts)


def _abstract_mesh():
    """The current abstract mesh, or None where JAX doesn't expose one.

    ``jax.sharding.get_abstract_mesh`` is a newer API; on older JAX (which
    also predates Manual axis types on abstract meshes) we fall back to the
    concrete bound mesh — correct here because the compressed-gradient path
    is pure pjit, never an actual shard_map manual region.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    return get()


def shard(x: jax.Array, axes: tuple) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    Inside a partial-manual shard_map region (e.g. the compressed-gradient
    path, manual over 'pod'), constraints must be built against the current
    *abstract* mesh — its axis types carry the Manual marking — and must not
    mention manual axes.
    """
    binding = _current()
    if binding is None:
        return x
    mesh, rules = binding
    abstract = _abstract_mesh()
    if abstract is not None and not abstract.empty:
        manual = {name for name, kind in zip(abstract.axis_names,
                                             abstract.axis_types)
                  if str(kind).endswith("Manual")}
        if manual:
            rules = _strip_axes(rules, manual)
            spec = resolve(axes, rules)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(abstract, spec))
    spec = resolve(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _strip_axes(rules: dict, banned: set) -> dict:
    out = {}
    for k, v in rules.items():
        if isinstance(v, tuple):
            v = tuple(a for a in v if a not in banned) or None
        elif v in banned:
            v = None
        out[k] = v
    return out


# ---------------------------------------------------------------- rule sets


def activation_rules(
    *,
    data_axes: tuple = ("pod", "data"),
    model_axis: str = "model",
    sequence_parallel: bool = False,
    shard_cache_seq: bool = False,
) -> dict:
    """Standard rule set for the (pod, data, model) production mesh.

    - ``batch``/``dp`` over the pure-DP axes,
    - heads / ff / vocab / experts over the tensor axis,
    - ``seq``: sharded over the tensor axis between blocks iff
      ``sequence_parallel`` (the SP hillclimb lever),
    - ``cache_seq``: KV-cache sequence axis; sharding it over the tensor
      axis is the flash-decode/split-K lever for MQA decode.
    """
    return {
        "batch": data_axes,
        "dp": data_axes,
        "seq": model_axis if sequence_parallel else None,
        "heads": model_axis,
        "kv_heads": model_axis,
        "ff": model_axis,
        "vocab": model_axis,
        "expert": model_axis,
        "rnn": model_axis,
        "cache_seq": model_axis if shard_cache_seq else None,
        "fsdp": "data",
        "embed": None,
    }
