from .ctx import activation_rules, shard, use_rules
from .specs import param_logical_axes, param_specs, logical_to_spec

__all__ = [
    "activation_rules",
    "logical_to_spec",
    "param_logical_axes",
    "param_specs",
    "shard",
    "use_rules",
]
