"""Parameter partition specs, derived — not stored.

Logical axes for every parameter are *computed* from the parameter tree's
path structure (the paper's hierarchical deterministic naming applied to
shardings: given (arch, mesh, rules), every placement is recomputable;
nothing about layout is ever persisted).

Param logical-axis vocabulary:
  embed_p — model width dim of params      -> FSDP axis ("data")
  vocab   — vocabulary dim                 -> tensor axis ("model")
  heads   — attention heads                -> tensor axis
  ff      — MLP hidden / mLSTM inner dim   -> tensor axis
  expert  — MoE expert dim                 -> tensor axis (EP)
  rnn     — RG-LRU recurrence width        -> tensor axis
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey


PARAM_RULES = {
    "embed_p": "data",
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "expert": "model",
    "rnn": "model",
}


def _leaf_axes(names: list, rank: int) -> tuple:
    """Logical axes for a parameter leaf, by name + context + rank."""
    name = names[-1]
    ctx = set(names)

    def r(*axes):
        assert len(axes) == rank, (names, rank, axes)
        return tuple(axes)

    if name == "table":
        return r("vocab", "embed_p")
    if name == "w" and "frontend" in ctx:
        return r(None, "embed_p")
    if name == "w" and "head" in ctx:
        return r("embed_p", "vocab")
    if name in ("scale",):
        return r(None)
    if "slstm" in ctx:
        if name in ("w_z", "w_i", "w_f", "w_o"):
            return r("embed_p", None)
        if name.startswith("r_"):
            return r("heads", None, None)
        if name == "w_o_proj":
            return r("embed_p", None)
        if name.startswith("b_"):
            return r(None)
        # fall through for the inner ffn (w_gate/w_up/w_down)
    if "rglru" in ctx:
        if name in ("w_x", "w_g"):
            return r("embed_p", "rnn")
        if name == "conv_w":
            return r(None, "rnn")
        if name in ("conv_b", "b_a", "b_i", "lam"):
            return r("rnn")
        if name in ("w_a", "w_i"):
            return r(None, "rnn")
        if name == "w_o":
            return r("rnn", "embed_p")
    if "mlstm" in ctx:
        if name == "w_up":
            return r("embed_p", "ff")
        if name == "conv_w":
            return r(None, "ff")
        if name == "conv_b":
            return r("ff")
        if name in ("wq", "wk", "wv"):
            return r("ff", "heads", None)
        if name in ("w_i", "w_f"):
            return r("ff", None)
        if name in ("b_i", "b_f"):
            return r(None)
        if name == "w_down":
            return r("ff", "embed_p")
    if name in ("wq", "wk", "wv"):
        return r("embed_p", "heads", None)
    if name == "wo":
        return r("heads", None, "embed_p")
    if name in ("bq", "bk", "bv"):
        return r("heads", None)
    if name == "router":
        return r("embed_p", "expert")
    if name == "shared_gate":
        return r("embed_p", None)
    if name in ("w_gate", "w_up"):
        return r("expert", "embed_p", None) if rank == 3 else r("embed_p", "ff")
    if name == "w_down":
        return r("expert", None, "embed_p") if rank == 3 else r("ff", "embed_p")
    if name == "conv_w":
        return r(None, "ff")
    if name in ("conv_b", "lam"):
        return r("ff")
    # biases / scalars: replicated
    return tuple(None for _ in range(rank))


def param_logical_axes(params) -> object:
    """Pytree (matching params) of logical-axis tuples."""

    def f(path, leaf):
        names = [p.key for p in path if isinstance(p, DictKey)]
        stacked = any(isinstance(p, SequenceKey) for p in path) and "main" in names
        # "main" segment params carry a leading scanned-layer dim
        is_main = names and names[0] == "main"
        rank = leaf.ndim - (1 if is_main else 0)
        axes = _leaf_axes(names, rank)
        return ((None,) + axes) if is_main else axes

    return jax.tree_util.tree_map_with_path(f, params)


def logical_to_spec(axes: tuple, rules: dict) -> P:
    return P(*[rules.get(a) if a is not None else None for a in axes])


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (e.g. MQA kv=1 over a
    16-way tensor axis -> replicate that dim).  The resulting redundancy is
    visible in the roofline's MODEL_FLOPS/HLO ratio rather than hidden."""
    parts = []
    for i, p in enumerate(tuple(spec)[: len(shape)]):
        if p is None:
            parts.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(p if shape[i] % size == 0 else None)
    return P(*parts)


def param_specs(params, mesh: Mesh, rules: dict = PARAM_RULES):
    """Pytree of NamedShardings for a (possibly abstract) parameter tree."""
    logical = param_logical_axes(params)
    return jax.tree.map(
        lambda leaf, ax: NamedSharding(
            mesh, fit_spec(logical_to_spec(ax, rules), leaf.shape, mesh)),
        params, logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
