from .stream import StreamSource, batch_specs

__all__ = ["StreamSource", "batch_specs"]
