"""Deterministic, checkpointable streaming data source.

The paper's design principle "don't store what you can compute" (§7.1)
applied to the data plane: the source's entire durable state is **one
integer offset**.  Any batch is a pure function of (seed, offset), so:

- checkpointing the pipeline = recording the offset in the ConsistentRegion
  CRD (a few bytes, not a shuffle-buffer snapshot);
- rollback-and-recovery replays from the saved offset — exactly the
  at-least-once tuple semantics of the paper's consistent regions (§6.5);
- elastic width changes (different DP width ⇒ different per-shard batch
  slices) need no data reshuffling: slices are recomputed from the offset.

Two token generators:
- ``random``: iid tokens (throughput benchmarking);
- ``lcg``: a noisy affine next-token process — *learnable*, so end-to-end
  training demos show a genuinely decreasing loss.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class StreamSource:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    mode: str = "lcg"  # "lcg" | "random"
    noise: float = 0.05
    frontend_len: int = 0
    frontend_dim: int = 0

    def batch_at(self, offset: int) -> dict:
        """Pure function of (seed, offset) -> training batch."""
        key = jax.random.fold_in(jax.random.key(self.seed), offset)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        n = self.seq_len + 1
        if self.mode == "random":
            toks = jax.random.randint(k1, (self.batch, n), 0, self.vocab_size)
        else:
            # noisy affine chain: x_{t+1} = (a*x_t + c) mod V, with iid
            # corruption at rate ``noise`` — low-entropy, learnable.
            a = 8121 % self.vocab_size or 13
            c = 28411 % self.vocab_size
            x0 = jax.random.randint(k1, (self.batch,), 0, self.vocab_size)

            def step(x, knoise):
                nxt = (a * x + c) % self.vocab_size
                return nxt, nxt

            _, chain = jax.lax.scan(step, x0, jnp.arange(n - 1))
            toks = jnp.concatenate([x0[:, None], chain.T], axis=1)
            flip = jax.random.bernoulli(k2, self.noise, toks.shape)
            rand = jax.random.randint(k3, toks.shape, 0, self.vocab_size)
            toks = jnp.where(flip, rand, toks)
        batch = {
            "tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32),
        }
        if self.frontend_len:
            batch["frontend_embeds"] = jax.random.normal(
                k4, (self.batch, self.frontend_len, self.frontend_dim), jnp.float32)
        return batch


def batch_specs(vocab_size: int, batch: int, seq_len: int,
                frontend_len: int = 0, frontend_dim: int = 0) -> dict:
    """ShapeDtypeStructs for a training batch (dry-run input stand-ins)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if frontend_len:
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, frontend_len, frontend_dim), jnp.float32)
    return specs
