#!/usr/bin/env python
"""Docs checks (CI `docs` job).

Two guarantees, so the documentation cannot silently rot:

1. every backtick code reference in ``README.md`` / ``docs/ARCHITECTURE.md``
   that looks like a repo path resolves to a real file, and every
   ``python -m repro...`` invocation resolves to a real module under
   ``src/``;
2. every script in ``examples/`` at least imports cleanly (side-effect-free
   top level; their ``main()`` guards keep this cheap);
3. every platform/core module (``src/repro/platform``, ``src/repro/core``)
   is referenced at least once from ``docs/ARCHITECTURE.md`` — a new
   subsystem (e.g. ``scheduler.py``) cannot land undocumented.

Run from anywhere:  python scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("README.md", os.path.join("docs", "ARCHITECTURE.md"))

PATH_RE = re.compile(
    r"`([A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md|sh|json|yml|csv|txt))"
    r"(?::[0-9]+)?`")
MODULE_RE = re.compile(r"python[3]? -m (repro[A-Za-z0-9_.]*)")


def check_references() -> list:
    errors = []
    for doc in DOCS:
        full = os.path.join(ROOT, doc)
        if not os.path.exists(full):
            errors.append(f"{doc}: required document is missing")
            continue
        with open(full) as f:
            text = f.read()
        for match in PATH_RE.finditer(text):
            ref = match.group(1)
            if ref.startswith("results/"):
                # generated bench artifacts (gitignored): their existence is
                # gated by `benchmarks/run.py --smoke`, not by a checkout
                continue
            if not os.path.exists(os.path.join(ROOT, ref)):
                errors.append(f"{doc}: referenced path `{ref}` does not exist")
        for match in MODULE_RE.finditer(text):
            mod = match.group(1)
            rel = mod.replace(".", os.sep)
            if not (os.path.exists(os.path.join(ROOT, "src", rel + ".py"))
                    or os.path.isdir(os.path.join(ROOT, "src", rel))):
                errors.append(f"{doc}: `python -m {mod}` does not resolve "
                              f"under src/")
    return errors


def check_platform_modules_documented() -> list:
    """Every non-underscore module of the platform/core packages must be
    mentioned (by filename) somewhere in ARCHITECTURE.md."""
    arch = os.path.join(ROOT, "docs", "ARCHITECTURE.md")
    if not os.path.exists(arch):
        return []  # already reported by check_references
    with open(arch) as f:
        text = f.read()
    errors = []
    for pkg in ("src/repro/platform", "src/repro/core"):
        for name in sorted(os.listdir(os.path.join(ROOT, pkg))):
            if not name.endswith(".py") or name.startswith("_"):
                continue
            if name not in text:
                errors.append(
                    f"docs/ARCHITECTURE.md: platform module `{pkg}/{name}` "
                    f"is never referenced — document the subsystem")
    return errors


def check_examples_import() -> list:
    examples = sorted(
        f for f in os.listdir(os.path.join(ROOT, "examples"))
        if f.endswith(".py"))
    loader = "\n".join(
        "import importlib.util\n"
        f"spec = importlib.util.spec_from_file_location("
        f"'example_{i}', {os.path.join(ROOT, 'examples', name)!r})\n"
        "mod = importlib.util.module_from_spec(spec)\n"
        "spec.loader.exec_module(mod)\n"
        f"print('imported examples/{name}')"
        for i, name in enumerate(examples))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", loader], env=env,
                          capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        return [f"examples import check failed:\n{proc.stdout}\n{proc.stderr}"]
    print(proc.stdout, end="")
    return []


def main() -> int:
    errors = check_references()
    errors += check_platform_modules_documented()
    errors += check_examples_import()
    for err in errors:
        print(f"DOCS CHECK FAIL: {err}", file=sys.stderr)
    if not errors:
        print("docs checks OK "
              f"({', '.join(DOCS)} references resolve; examples import)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
