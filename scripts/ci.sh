#!/usr/bin/env bash
# Tier-1 verification + cheap benchmark smoke. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests"
python -m pytest -x -q

echo "== benchmark smoke (fig7c, table1, transport)"
# drop any stale artifact so run.py's --smoke BENCH_transport.json gate is real
rm -f results/BENCH_transport.json
python benchmarks/run.py --smoke

echo "CI OK"
