#!/usr/bin/env bash
# Tier-1 verification + cheap benchmark smoke. Run from the repo root.
#
# The tier-1 suite runs ~10 minutes serially, so CI splits it into two
# parallel shards via TIER1_SHARD=1|2 (unset = run everything — the local
# default).  Shard 2 names the heavy threaded files explicitly; shard 1 is
# *everything else minus slow-marked rows*, so a newly added test file
# always lands in shard 1 instead of being silently skipped.  The slow
# rows of the shard-1 files (the socket-backend transport matrix, the
# cross-process prochost suite) run as a second invocation on shard 2,
# next to the other heavyweights.  Shard 1 also carries the benchmark
# smoke + docs checks (its test half is the lighter one).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# the two heaviest files by --durations (~170 s of ~270 s serial); the
# remaining ~100 s of tests plus the bench smoke + docs checks balance out
# as shard 1
SHARD2=(
  tests/test_models.py
  tests/test_platform_e2e.py
)

shard="${TIER1_SHARD:-all}"
case "$shard" in
  1)
    echo "== tier-1 tests (shard 1: everything not in shard 2, minus slow rows)"
    ignores=()
    for f in "${SHARD2[@]}"; do ignores+=("--ignore=$f"); done
    python -m pytest -x -q --durations=20 -m "not slow" "${ignores[@]}"
    ;;
  2)
    echo "== tier-1 tests (shard 2: heaviest suites)"
    python -m pytest -x -q --durations=20 "${SHARD2[@]}"
    echo "== tier-1 tests (shard 2: slow rows of the shard-1 files)"
    ignores=()
    for f in "${SHARD2[@]}"; do ignores+=("--ignore=$f"); done
    python -m pytest -x -q --durations=20 -m slow "${ignores[@]}"
    ;;
  all)
    echo "== tier-1 tests"
    python -m pytest -x -q --durations=20
    ;;
  *)
    echo "unknown TIER1_SHARD='$shard' (want 1, 2, or unset)" >&2
    exit 2
    ;;
esac

if [ "$shard" = "2" ]; then
  echo "CI OK (shard 2: tests only)"
  exit 0
fi

echo "== benchmark smoke (fig7c, table1, transport, scale_down, scaleout, teardown, oversub, latency, chaos, recovery, serve)"
# drop stale artifacts so run.py's --smoke artifact gates are real
rm -f results/BENCH_transport.json results/BENCH_scaledown.json \
      results/BENCH_scaleout.json results/BENCH_teardown.json \
      results/BENCH_oversub.json results/BENCH_latency.json \
      results/BENCH_chaos.json results/BENCH_recovery.json \
      results/BENCH_serve.json
python benchmarks/run.py --smoke

echo "== docs checks (README/ARCHITECTURE references, examples import)"
python scripts/check_docs.py

echo "CI OK"
