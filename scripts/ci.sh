#!/usr/bin/env bash
# Tier-1 verification + cheap benchmark smoke. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests"
python -m pytest -x -q

echo "== benchmark smoke (fig7c, table1, transport, scale_down, teardown, oversub)"
# drop stale artifacts so run.py's --smoke artifact gates are real
rm -f results/BENCH_transport.json results/BENCH_scaledown.json \
      results/BENCH_teardown.json results/BENCH_oversub.json
python benchmarks/run.py --smoke

echo "== docs checks (README/ARCHITECTURE references, examples import)"
python scripts/check_docs.py

echo "CI OK"
