#!/usr/bin/env bash
# Tier-1 verification + cheap benchmark smoke. Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests"
python -m pytest -x -q

echo "== benchmark smoke (thread-free subset)"
python benchmarks/run.py --smoke

echo "CI OK"
