"""Recovery plane: StandbyPolicy CRD shape, the conductor-driven
checkpoint sweep (``.committing`` marker honored), warm-standby placement
+ promotion end to end under a tight recovery-time SLO, and the degraded
``standby-loss`` path that falls back to the cold restart chain.
"""

import pytest

from repro.ckpt import CheckpointStore
from repro.core import (
    Event,
    EventType,
    ResourceStore,
    condition_is,
    wait_for,
)
from repro.platform import Platform, crds
from repro.platform.failover import FailoverConductor


# ------------------------------------------------------------- CRD contract


def test_standby_policy_crd_shape():
    pol = crds.make_standby_policy("app", pes=[1, 3], warm_interval=0.25)
    assert pol.name == crds.standby_policy_name("app") == "app-standby"
    assert pol.spec == {"job": "app", "pes": [1, 3], "warmInterval": 0.25}
    # conductor-owned progress fields exist from birth
    assert pol.status == {"protected": {}, "promotions": 0}
    assert pol.labels == crds.job_labels("app")
    # empty pes = protect every non-source PE (resolved at reconcile time)
    assert crds.make_standby_policy("app").spec["pes"] == []

    sb = crds.make_standby_pod("app", 2, {"pod_spec": {}}, 4, 1)
    assert sb.name == crds.standby_pod_name("app", 2) == "app-standby-2"
    assert sb.spec["standby"] is True
    assert sb.spec["launchCount"] == 4


# ------------------------------------------- conductor-driven sweep (unit)


def _cr_event(seq, *, job, region, committed, old_committed=None):
    spec = {"interval": 1.0, "members": [1]}
    cr = crds.make_consistent_region(job, region, spec)
    cr.status["lastCommitted"] = committed
    old = None
    if old_committed is not None:
        old = crds.make_consistent_region(job, region, spec)
        old.status["lastCommitted"] = old_committed
    return Event(seq=seq, type=EventType.MODIFIED, resource=cr, old=old)


def test_conductor_sweep_on_commit(tmp_path):
    """A CR commit event reaps strictly-older uncommitted steps; the
    ``.committing`` marker spares a step whose CRD write may still be in
    flight; a repeat event for the same committed step is a no-op."""
    ck = CheckpointStore(str(tmp_path))
    for step in (1, 2, 3, 4):
        ck.save_shard("j", "r", step, "pe1", meta={"step": step})
    ck.mark_committing("j", "r", 2)

    store = ResourceStore()
    fc = FailoverConductor(store, "default", None, ckpt=ck)
    fc.on_event(_cr_event(1, job="j", region="r", committed=3,
                          old_committed=-1))
    # steps 1 reaped; 2 spared (.committing); 3 is the commit; 4 newer
    assert fc.sweeps == 1
    assert ck.load_shard("j", "r", 1, "pe1")[1] is None
    assert ck.load_shard("j", "r", 2, "pe1")[1] == {"step": 2}
    assert ck.load_shard("j", "r", 3, "pe1")[1] == {"step": 3}
    assert ck.load_shard("j", "r", 4, "pe1")[1] == {"step": 4}
    # same committed step again: no new commit, nothing swept
    fc.on_event(_cr_event(2, job="j", region="r", committed=3,
                          old_committed=3))
    assert fc.sweeps == 1
    # marker cleared -> the next commit reaps the spared step too
    ck.clear_committing("j", "r", 2)
    fc.on_event(_cr_event(3, job="j", region="r", committed=4,
                          old_committed=3))
    assert ck.load_shard("j", "r", 2, "pe1")[1] is None
    assert ck.load_shard("j", "r", 3, "pe1")[1] is None


# ------------------------------------------------- threaded e2e (shard 2)


@pytest.fixture
def platform():
    p = Platform(num_nodes=4)
    yield p
    p.shutdown()


@pytest.mark.slow
def test_warm_standby_promotion_e2e(platform):
    """The tentpole path end to end: the policy places a shadow pod on a
    *different* node (anti-affinity pairing), a primary kill promotes it in
    place (single epoch bump, no restart chain), the recover span stays
    inside a tight 1 s recovery-time SLO, and the conductor re-warms a
    fresh standby behind the promoted primary.  Policy teardown reaps the
    shadow and clears readiness."""
    p = platform
    p.submit("wj", {"app": {"type": "streams", "width": 2,
                            "pipeline_depth": 1,
                            "source": {"rate_sleep": 0.002}}})
    assert p.wait_full_health("wj", 60)
    p.set_standby_policy("wj", pes=[1], warm_interval=0.2)
    assert wait_for(lambda: p.api.pes.condition_is(
        crds.pe_name("wj", 1), crds.COND_STANDBY_READY), 20)

    sb = p.api.pods.get(crds.standby_pod_name("wj", 1))
    pr = p.api.pods.get(crds.pod_name("wj", 1))
    assert sb.spec["nodeName"] != pr.spec["nodeName"]  # pair split apart
    assert sb.status.get("warmed")  # readiness came from the runtime
    # only the named PE is shadowed
    assert [pod.name for pod in p.pods("wj") if pod.spec.get("standby")] \
        == [sb.name]

    p.set_slo("wj", loss_budget=256, recovery_time_s=1.0)
    before = pr.spec.get("launchCount", 0)
    p.trace.clear()
    assert p.kill_pod("wj", 1)

    def promoted():
        pod = p.api.pods.try_get(crds.pod_name("wj", 1))
        return (pod is not None
                and pod.spec.get("launchCount", 0) > before
                and pod.status.get("phase") == "Running"
                and bool(pod.status.get("connected")))
    assert wait_for(promoted, 20)
    assert p.failover.promotions == 1
    assert p.failover.degraded_failovers == 0

    spans = [s for s in p.trace.spans(name="recover")
             if s.attrs.get("job") == "wj" and s.t1 is not None]
    assert spans and all(s.duration_ms < 1000.0 for s in spans)

    # promotion completed: condition cleared, policy counted it, and a
    # fresh standby re-warms behind the promoted primary
    assert wait_for(lambda: p.api.pes.condition_is(
        crds.pe_name("wj", 1), crds.COND_STANDBY_READY), 20)
    pe = p.api.pes.get(crds.pe_name("wj", 1))
    assert not condition_is(pe, crds.COND_PROMOTING)
    pol = p.api.standby_policies.get(crds.standby_policy_name("wj"))
    assert pol.status.get("promotions") == 1
    assert p.wait_full_health("wj", 30)

    # the recover span is inside the judged bound
    verdict = p.slo_conductor.evaluate("wj", force=True)
    conds = {c["type"]: c["status"]
             for c in p.slo_status("wj").get("conditions", [])}
    assert conds.get("Met") == "True" and conds.get("Violated") == "False", \
        (verdict, conds)

    p.delete_standby_policy("wj")
    assert wait_for(lambda: not p.api.pods.exists(
        crds.standby_pod_name("wj", 1)), 15)
    assert wait_for(lambda: not p.api.pes.condition_is(
        crds.pe_name("wj", 1), crds.COND_STANDBY_READY), 15)


@pytest.mark.slow
def test_standby_loss_degraded_recovery(platform):
    """``standby-loss``: the shadow dies right before the primary, so the
    promotion finds no live handle to adopt and degrades to the cold
    restart chain — the PE still recovers, and the conductor re-warms a
    fresh standby afterwards."""
    p = platform
    p.submit("dj", {"app": {"type": "streams", "width": 2,
                            "pipeline_depth": 1,
                            "source": {"rate_sleep": 0.002}}})
    assert p.wait_full_health("dj", 60)
    p.set_slo("dj", loss_budget=256, recovery_time_s=30.0)
    st = p.run_scenario(fault="standby-loss", job="dj", seed=106,
                        target={"minPe": 1}, timeout=90)
    assert st["completed"], st
    assert st["phase"] == "Recovered"
    assert st["outcome"]["degraded"] is True
    assert st["outcome"]["reWarmed"] is True
    assert p.wait_full_health("dj", 30)
    verdict = p.slo_conductor.evaluate("dj", force=True)
    conds = {c["type"]: c["status"]
             for c in p.slo_status("dj").get("conditions", [])}
    assert conds.get("Met") == "True", (verdict, conds)
