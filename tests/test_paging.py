"""Property tests for the paged KV-cache control plane (serve/paging.py).

Randomized op sequences against the allocator invariants (no double-use,
no leak, free-list conservation), plus directed tests for the sequence
block lists and the block-granular prefix cache.
"""

import random

import pytest

from repro.serve.paging import (
    BlockAllocator,
    OutOfBlocks,
    PrefixCache,
    SequenceBlocks,
)


# ---------------------------------------------------------------- allocator


def test_allocator_basic_invariants():
    a = BlockAllocator(num_blocks=8, block_size=4)
    assert a.capacity == 7  # block 0 reserved as scratch
    blocks = [a.alloc() for _ in range(7)]
    assert BlockAllocator.SCRATCH not in blocks
    assert len(set(blocks)) == 7  # no double-use
    with pytest.raises(OutOfBlocks):
        a.alloc()
    for b in blocks:
        a.decref(b)
    assert a.blocks_free == a.capacity  # no leak
    a.check()


def test_allocator_random_property(seed_runs=20):
    """Random alloc/incref/decref/cow traffic preserves conservation."""
    for seed in range(seed_runs):
        rng = random.Random(seed)
        a = BlockAllocator(num_blocks=rng.randint(2, 24), block_size=4)
        held = []  # one entry per reference we own
        for _ in range(200):
            op = rng.random()
            if op < 0.4:
                try:
                    held.append(a.alloc())
                except OutOfBlocks:
                    assert a.blocks_free == 0
            elif op < 0.6 and held:
                b = rng.choice(held)
                a.incref(b)
                held.append(b)
            elif op < 0.85 and held:
                a.decref(held.pop(rng.randrange(len(held))))
            elif held:
                b = held.pop(rng.randrange(len(held)))
                try:
                    new, src = a.cow(b)
                except OutOfBlocks:
                    held.append(b)
                    continue
                if src is None:
                    assert new == b  # exclusive: write in place
                else:
                    assert src == b and new != b
                    assert a.ref(src) >= 1  # other owners keep it alive
                held.append(new)
            a.check()
        for b in held:
            a.decref(b)
        a.check()
        assert a.blocks_free == a.capacity  # every reference returned


def test_cow_shared_vs_exclusive():
    a = BlockAllocator(num_blocks=4, block_size=2)
    b = a.alloc()
    assert a.cow(b) == (b, None)  # refcount 1: in-place
    a.incref(b)
    new, src = a.cow(b)  # refcount 2: diverge
    assert src == b and new != b
    assert a.ref(b) == 1 and a.ref(new) == 1
    a.decref(b)
    a.decref(new)
    a.check()


# ----------------------------------------------------------- sequence blocks


def test_sequence_capacity_is_all_or_nothing():
    a = BlockAllocator(num_blocks=4, block_size=2)  # capacity 3
    s = SequenceBlocks(a)
    s.ensure_capacity(4)  # 2 blocks
    s.length = 4
    free_before = a.blocks_free
    with pytest.raises(OutOfBlocks):
        s.ensure_capacity(4)  # would need 2 more, only 1 free
    assert a.blocks_free == free_before  # no partial allocation
    s.free()
    assert a.blocks_free == a.capacity
    a.check()


def test_sequence_writable_triggers_cow_on_shared_tail():
    a = BlockAllocator(num_blocks=6, block_size=4)
    donor = SequenceBlocks(a)
    donor.ensure_capacity(6)  # blocks [x, y]; tail block half full
    donor.length = 6
    tail = donor.blocks[1]
    a.incref(tail)  # simulate a cache/another request sharing the tail
    adopter = SequenceBlocks(a)
    adopter.adopt([tail], 2)
    dst, src = adopter.ensure_writable()
    assert src == tail and dst != tail  # CoW: copy before appending
    assert adopter.blocks == [dst]
    assert donor.blocks[1] == tail  # donor untouched
    dst2, src2 = adopter.ensure_writable()
    assert (dst2, src2) == (dst, None)  # now exclusive
    donor.free()
    adopter.free()
    a.check()
    assert a.blocks_free == a.capacity


# -------------------------------------------------------------- prefix cache


def _committed_seq(a, cache, tokens):
    """Prefill-and-commit helper: allocate blocks for tokens, insert."""
    s = SequenceBlocks(a)
    s.ensure_capacity(len(tokens))
    s.length = len(tokens)
    cache.insert(tokens, s.blocks, len(tokens))
    return s


def test_prefix_cache_match_and_refcounts():
    a = BlockAllocator(num_blocks=16, block_size=2)
    cache = PrefixCache(a)
    tokens = [1, 2, 3, 4, 5, 6]
    s = _committed_seq(a, cache, tokens)
    assert cache.blocks_cached == 3
    # identical prompt: matches at most len-1 tokens -> 2 full blocks
    blocks, n, tail_shared = cache.match(list(tokens))
    assert n == 4 and blocks == s.blocks[:2] and not tail_shared
    for b in blocks:  # match increfs on behalf of the adopter
        assert a.ref(b) == 3  # seq + cache + adopter
        a.decref(b)
    # diverging prompt shares only the common blocks
    blocks, n, _ = cache.match([1, 2, 9, 9, 9])
    assert n == 2 and blocks == s.blocks[:1]
    a.decref(blocks[0])
    # freeing the committer leaves the cache's copies alive
    s.free()
    blocks, n, _ = cache.match(list(tokens))
    assert n == 4
    for b in blocks:
        a.decref(b)
    a.check()


def test_prefix_cache_partial_tail_adoption():
    a = BlockAllocator(num_blocks=16, block_size=4)
    cache = PrefixCache(a)
    s = _committed_seq(a, cache, [1, 2, 3, 4, 5, 6])  # 1 full + tail(2)
    blocks, n, tail_shared = cache.match([1, 2, 3, 4, 5, 6, 7, 8])
    assert n == 6 and tail_shared and blocks == s.blocks
    adopter = SequenceBlocks(a)
    adopter.adopt(blocks, n)
    dst, src = adopter.ensure_writable()
    assert src == blocks[-1] and dst != src  # shared tail must CoW
    s.free()
    adopter.free()
    cache.evict(10)
    a.check()
    assert a.blocks_free == a.capacity


def test_prefix_cache_lru_eviction_skips_referenced():
    a = BlockAllocator(num_blocks=8, block_size=2)
    cache = PrefixCache(a)
    s1 = _committed_seq(a, cache, [1, 2, 3, 4])
    s2 = _committed_seq(a, cache, [5, 6])
    s1.free()
    s2.free()
    # both cached chains are now exclusively cache-owned; s1 is older
    blocks, n, _ = cache.match([1, 2, 3, 4, 9])  # touch s1's chain (MRU)
    for b in blocks:
        a.decref(b)
    assert cache.evict(1) == 1  # evicts s2's leaf (LRU)
    assert cache.match([5, 6, 7])[1] == 0
    blocks, n, _ = cache.match([1, 2, 3, 4, 9])
    assert n == 4  # s1 chain survives
    for b in blocks:
        a.decref(b)
    cache.evict(10)
    a.check()
    assert a.blocks_free == a.capacity


def test_prefix_cache_hit_rate_counters():
    a = BlockAllocator(num_blocks=8, block_size=2)
    cache = PrefixCache(a)
    _committed_seq(a, cache, [1, 2, 3, 4])
    assert cache.hit_rate == 0.0
    cache.match([1, 2, 9])
    cache.match([7, 7, 7])
    assert cache.lookups == 2 and cache.hits == 1
    assert cache.hit_rate == 0.5
