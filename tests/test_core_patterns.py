"""Core patterns: store semantics, controllers/conductors/coordinators, and
the paper's determinism claim (§4) as a property test — random event
interleavings converge to the same final state."""

import os
import threading

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    AlreadyExistsError,
    CausalTrace,
    Conductor,
    ConflictError,
    Controller,
    Coordinator,
    EventType,
    NotFoundError,
    OwnerRef,
    Resource,
    ResourceStore,
    Runtime,
    TerminatingError,
)


# ------------------------------------------------------------------- store


def test_store_crud_and_versions():
    s = ResourceStore()
    r = s.create(Resource(kind="Job", name="a", spec={"x": 1}))
    assert r.resource_version == 1 and r.generation == 1
    r2 = s.update("Job", "a", lambda res: res.spec.update(x=2))
    assert r2.generation == 2  # spec change bumps generation
    r3 = s.update_status("Job", "a", {"state": "Up"})
    assert r3.generation == 2  # status change does not
    with pytest.raises(AlreadyExistsError):
        s.create(Resource(kind="Job", name="a"))
    s.delete("Job", "a")
    with pytest.raises(NotFoundError):
        s.get("Job", "a")


def test_store_cas_conflict():
    s = ResourceStore()
    s.create(Resource(kind="Job", name="a"))
    stale = s.get("Job", "a")
    s.update("Job", "a", lambda r: r.spec.update(x=1))
    with pytest.raises(ConflictError):
        s.replace(stale, expected_version=stale.resource_version)


def test_watch_replay_full_history():
    s = ResourceStore()
    s.create(Resource(kind="Job", name="a"))
    s.update("Job", "a", lambda r: r.spec.update(x=1))
    s.delete("Job", "a")
    sub = s.watch(kinds=("Job",), replay=True)
    events = [sub.poll() for _ in range(3)]
    assert [e.type for e in events] == [EventType.ADDED, EventType.MODIFIED,
                                        EventType.DELETED]
    assert [e.seq for e in events] == [1, 2, 3]  # total order


def test_wal_recovery(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    s = ResourceStore(wal_path=wal)
    s.create(Resource(kind="Job", name="a", spec={"x": 1}))
    s.create(Resource(kind="Pod", name="p"))
    s.update("Job", "a", lambda r: r.spec.update(x=5))
    s.delete("Pod", "p")
    s.close()
    s2 = ResourceStore.recover(wal)
    assert s2.get("Job", "a").spec["x"] == 5
    assert s2.try_get("Pod", "p") is None
    assert s2.seq == 4


def test_owner_gc_vs_bulk_delete():
    s = ResourceStore()
    s.create(Resource(kind="Job", name="j", labels={"job": "j"}))
    for i in range(5):
        s.create(Resource(kind="Pod", name=f"p{i}", labels={"job": "j"},
                          owner_refs=(OwnerRef("Job", "j"),)))
        s.create(Resource(kind="ConfigMap", name=f"c{i}", labels={"job": "j"},
                          owner_refs=(OwnerRef("Pod", f"p{i}"),)))
    s.delete("Job", "j")
    removed = s.gc_collect()  # cascading: pods then configmaps
    assert removed == 10
    # bulk path
    s.create(Resource(kind="Job", name="k", labels={"job": "k"}))
    for i in range(5):
        s.create(Resource(kind="Pod", name=f"q{i}", labels={"job": "k"}))
    n = s.delete_collection(label_selector={"job": "k"})
    assert n == 6


# ------------------------------------------------------ controller semantics


class CountingController(Controller):
    def __init__(self, store, kind):
        super().__init__(store, kind)
        self.adds, self.mods, self.dels = [], [], []

    def on_addition(self, res):
        self.adds.append(res.name)

    def on_modification(self, old, new):
        self.mods.append((old.spec.get("x") if old else None, new.spec.get("x")))

    def on_deletion(self, res):
        self.dels.append(res.name)


def test_controller_callbacks_and_cache():
    s = ResourceStore()
    c = CountingController(s, "Job")
    rt = Runtime(s, threaded=False)
    rt.register(c)
    s.create(Resource(kind="Job", name="a", spec={"x": 1}))
    s.update("Job", "a", lambda r: r.spec.update(x=2))
    s.create(Resource(kind="Pod", name="p"))  # different kind: filtered
    s.delete("Job", "a")
    rt.drain()
    assert c.adds == ["a"] and c.mods == [(1, 2)] and c.dels == ["a"]
    assert c.cache == {}


def test_conductor_receives_from_multiple_controllers():
    s = ResourceStore()
    seen = []

    class C(Conductor):
        kinds = ("Job", "Pod")

        def on_event(self, event):
            seen.append((event.resource.kind, event.type))

    ca, cb = Controller(s, "Job"), Controller(s, "Pod")
    cond = C(s)
    ca.add_listener(cond)
    cb.add_listener(cond)
    rt = Runtime(s, threaded=False)
    rt.register(ca)
    rt.register(cb)
    s.create(Resource(kind="Job", name="a"))
    s.create(Resource(kind="Pod", name="p"))
    rt.drain()
    assert ("Job", EventType.ADDED) in seen and ("Pod", EventType.ADDED) in seen


def test_coordinator_serializes_concurrent_writers():
    s = ResourceStore()
    s.create(Resource(kind="PE", name="pe", status={"launchCount": 0}))
    coord = Coordinator(s, "PE")
    n_threads, n_incr = 8, 50

    def bump():
        for _ in range(n_incr):
            coord.submit("pe", lambda r: r.status.update(
                launchCount=r.status["launchCount"] + 1))

    threads = [threading.Thread(target=bump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert s.get("PE", "pe").status["launchCount"] == n_threads * n_incr


# --------------------------------------------------- determinism (property)


class LaunchController(Controller):
    """PE-controller-like: new resource -> coordinator bumps launchCount."""

    def __init__(self, store, coord):
        super().__init__(store, "PE")
        self.coord = coord

    def on_addition(self, res):
        self.coord.submit(res.name, lambda r: r.status.update(
            launchCount=r.status.get("launchCount", 0) + 1))


class PodCreator(Conductor):
    """Pod-conductor-like: launchCount changes -> create pods."""

    kinds = ("PE",)

    def on_event(self, event):
        if event.type == EventType.DELETED:
            return
        res = event.resource
        want = res.status.get("launchCount", 0)
        if want < 1:
            return
        pod_name = f"pod-{res.name}"
        pod = self.store.try_get("Pod", pod_name)
        if pod is None:
            self.store.create(Resource(kind="Pod", name=pod_name,
                                       spec={"launch": want}))
        elif pod.spec["launch"] < want:
            self.store.update("Pod", pod_name,
                              lambda r: r.spec.update(launch=want))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=0, max_size=60),
       st.integers(2, 6))
def test_causal_chain_deterministic_under_interleaving(schedule, n_pes):
    """Any interleaving of event delivery yields the same final state."""
    s = ResourceStore()
    pe_coord = Coordinator(s, "PE")
    ctrl = LaunchController(s, pe_coord)
    pod_ctrl = Controller(s, "Pod")
    cond = PodCreator(s)
    ctrl.add_listener(cond)
    rt = Runtime(s, threaded=False)
    rt.register(ctrl)
    rt.register(pod_ctrl)
    for i in range(n_pes):
        s.create(Resource(kind="PE", name=f"pe{i}"))
    it = iter(schedule)

    def order(nonempty):
        try:
            return nonempty[next(it) % len(nonempty)]
        except StopIteration:
            return nonempty[0]

    rt.drain(order=order)
    pods = s.list(kind="Pod")
    assert len(pods) == n_pes
    for p in pods:
        assert p.spec["launch"] == 1
    for pe in s.list(kind="PE"):
        assert pe.status["launchCount"] == 1


class Drainer(Controller):
    """Drain-controller-like: observes an owned kind becoming terminating
    (two-phase delete stamped) and, after its 'drain' completes, removes
    the finalizer — the reap trigger."""

    FINALIZER = "streams/drain"

    def __init__(self, store, kind):
        super().__init__(store, kind)
        self.drained: list = []

    def on_modification(self, old, new):
        if new.terminating and self.FINALIZER in new.finalizers:
            self.drained.append(new.name)
            self.store.remove_finalizer(new.kind, new.name, self.FINALIZER,
                                        namespace=new.namespace)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=0, max_size=80),
       st.integers(1, 5))
def test_finalizer_deletion_converges_under_interleaving(schedule, n_pods):
    """Two-phase deletion racing finalizer removal: any event-delivery
    order converges to every finalized object reaped exactly once."""
    s = ResourceStore()
    drainer = Drainer(s, "Pod")
    rt = Runtime(s, threaded=False)
    rt.register(drainer)
    for i in range(n_pods):
        s.create(Resource(kind="Pod", name=f"p{i}",
                          finalizers=[Drainer.FINALIZER]))
        s.delete("Pod", f"p{i}")  # stamps; the drainer will release it
    it = iter(schedule)

    def order(nonempty):
        try:
            return nonempty[next(it) % len(nonempty)]
        except StopIteration:
            return nonempty[0]

    rt.drain(order=order)
    assert s.list(kind="Pod") == []  # everything reaped
    deleted = [e.resource.name for e in s.event_log
               if e.type == EventType.DELETED]
    assert sorted(deleted) == sorted(f"p{i}" for i in range(n_pods))
    assert len(deleted) == len(set(deleted))  # exactly once each
    assert sorted(set(drainer.drained)) == sorted(f"p{i}"
                                                  for i in range(n_pods))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=0, max_size=120),
       st.integers(1, 4))
def test_foreground_cascade_converges_under_adversarial_drains(schedule,
                                                               n_pes):
    """Foreground cascade over a Job -> PE -> Pod tree whose pods drain
    asynchronously (finalizer removed only when the drain controller gets
    around to it, in an adversarial order): the tree always empties, the
    job reaps last, and gc_collect is never needed."""
    s = ResourceStore()
    drainer = Drainer(s, "Pod")
    pe_ctrl = Controller(s, "PE")
    job_ctrl = Controller(s, "Job")
    rt = Runtime(s, threaded=False)
    rt.register(drainer)
    rt.register(pe_ctrl)
    rt.register(job_ctrl)
    s.create(Resource(kind="Job", name="j", labels={"job": "j"}))
    for i in range(n_pes):
        s.create(Resource(kind="PE", name=f"pe{i}", labels={"job": "j"},
                          owner_refs=(OwnerRef("Job", "j"),)))
        s.create(Resource(kind="Pod", name=f"pod{i}", labels={"job": "j"},
                          owner_refs=(OwnerRef("PE", f"pe{i}"),),
                          finalizers=[Drainer.FINALIZER]))
    s.delete("Job", "j", propagation="foreground")
    assert s.exists("Job", "j")  # held open by the draining pods
    it = iter(schedule)

    def order(nonempty):
        try:
            return nonempty[next(it) % len(nonempty)]
        except StopIteration:
            return nonempty[0]

    rt.drain(order=order)
    assert s.list(label_selector={"job": "j"}) == []
    assert s.gc_runs == 0
    deleted = [e.resource.kind for e in s.event_log
               if e.type == EventType.DELETED]
    assert deleted[-1] == "Job"  # owner reaps last, dependents first
    assert len(deleted) == 2 * n_pes + 1  # exactly once each


def test_delete_racing_finalizer_addition_is_rejected():
    """The convergence guarantee's other half: once deletion is stamped, a
    racing actor cannot extend the object's life with a new finalizer."""
    s = ResourceStore()
    s.create(Resource(kind="Pod", name="p", finalizers=["a"]))
    s.delete("Pod", "p")
    with pytest.raises(TerminatingError):
        s.update("Pod", "p", lambda r: r.finalizers.append("b"))
    s.remove_finalizer("Pod", "p", "a")
    assert not s.exists("Pod", "p")


def test_causal_trace_records_chain():
    s = ResourceStore()
    trace = CausalTrace()
    pe_coord = Coordinator(s, "PE", trace=trace)
    ctrl = LaunchController(s, pe_coord)
    ctrl.trace = trace
    rt = Runtime(s, threaded=False)
    rt.register(ctrl)
    s.create(Resource(kind="PE", name="pe0"))
    rt.drain()
    chain = trace.chain()
    assert any("pe-coordinator:modify" in c for c in chain)
    assert any("observe-add" in c for c in chain)
