"""Observability plane: span tracing, latency watermarks + digests, the
metrics plane's sample hygiene, and the SLO verdict plane.

Unit layers first (ring bound, P² accuracy, tracer parenting/export, metrics
dedupe + retired-drop ledger + job-delete pruning, SLO judging), then the
threaded acceptance runs: a drain and a rebalance must each render a
parented span chain end to end, and an SLO over a live job must reach a
verdict with a populated error-budget ledger.
"""

import json
import threading
import time

import pytest

from repro.core import (
    CausalTrace,
    Coordinator,
    Event,
    EventType,
    ResourceStore,
    wait_for,
)
from repro.platform import Platform, crds
from repro.platform.fabric import LatencyDigest, P2Quantile
from repro.platform.metrics import MetricsPlane
from repro.platform.slo import SLOConductor
from repro.platform.tracing import (
    SpanTracer,
    drain_token,
    migrate_token,
    span_tracer,
)


# ------------------------------------------------------------ trace ring


def test_causal_trace_ring_bound():
    """Satellite: the flat trace is a ring — unbounded soak runs must not
    grow it forever, and the chain()/actors_for() API survives eviction."""
    t = CausalTrace(maxlen=5)
    for i in range(12):
        t.record("actor", "act", ("Pod", "default", f"p{i}"), str(i))
    assert len(t.entries) == 5
    assert [e[3] for e in t.entries] == ["7", "8", "9", "10", "11"]
    assert t.actors_for(("Pod", "default", "p11")) == ["actor"]
    assert t.chain() == [f"actor:act:Pod/p{i}:{i}" for i in range(7, 12)]
    # default construction stays bounded too
    assert CausalTrace().entries.maxlen is not None


# ---------------------------------------------------------------- P² digest


def test_p2_quantile_tracks_known_distribution():
    # a deterministic shuffle of 1..n: P² must land near the true quantiles
    n = 5000
    xs = [((i * 2654435761) % n) + 1 for i in range(n)]  # Knuth hash permute
    assert len(set(xs)) == n
    for q in (0.5, 0.95, 0.99):
        est = P2Quantile(q)
        for x in xs:
            est.add(float(x))
        assert est.value() == pytest.approx(q * n, rel=0.05), f"q={q}"


def test_p2_quantile_small_samples_exact():
    est = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0):
        est.add(x)
    assert est.value() == 3.0  # n <= 5: exact order statistic, no markers


def test_latency_digest_snapshot_shape():
    d = LatencyDigest()
    assert d.snapshot_ms() == {}  # no samples yet: no keys published
    for ms in range(1, 101):
        d.observe(ms / 1000.0)
    snap = d.snapshot_ms()
    assert set(snap) == {"latencyP50", "latencyP95", "latencyP99",
                        "latencyMax", "latencySamples"}
    assert snap["latencySamples"] == 100
    assert snap["latencyMax"] == pytest.approx(100.0, abs=0.01)
    assert 40 < snap["latencyP50"] < 60
    assert snap["latencyP50"] < snap["latencyP95"] <= snap["latencyMax"]


# -------------------------------------------------------------- span tracer


def test_span_tracer_parents_and_renders():
    now = [100.0]
    tr = SpanTracer(clock=lambda: now[0])
    with tr.span("a", "root", ("Pod", "default", "p")) as root:
        now[0] += 0.010
        with tr.span("b", "child", ("Pod", "default", "p")) as child:
            now[0] += 0.005
    assert child.parent_id == root.span_id  # thread-local auto-parenting
    assert child.trace_id == root.trace_id
    assert root.duration_ms == pytest.approx(15.0)
    assert child.duration_ms == pytest.approx(5.0)
    text = tr.render(root)
    assert text.splitlines()[0].startswith("root Pod/p [a] 15.0ms")
    assert text.splitlines()[1].startswith("  child Pod/p [b] 5.0ms")
    # finished spans mirror into the flat trace with a distinct action
    assert "a:span:root:Pod/p:15.0ms" in tr.chain()


def test_span_tracer_token_context_crosses_threads():
    tr = SpanTracer()
    root = tr.start_span("armer", "drain", ("Pod", "default", "p"))
    tr.attach(drain_token("p"), root)
    got = {}

    def reactor():
        parent = tr.context(drain_token("p"))
        sp = tr.start_span("reactor", "begin-drain", ("Pod", "default", "p"),
                           parent=parent)
        tr.end_span(sp)
        got["span"] = sp

    th = threading.Thread(target=reactor)
    th.start()
    th.join()
    assert got["span"].parent_id == root.span_id
    assert tr.detach(drain_token("p")) is root
    assert tr.context(drain_token("p")) is None  # detach is consuming
    tr.end_span(root)
    tr.end_span(root)  # idempotent: second end is a no-op
    assert len([e for e in tr.entries if e[1] == "span:drain"]) == 1


def test_span_tracer_chrome_export(tmp_path):
    tr = SpanTracer()
    with tr.span("a", "root", ("Pod", "default", "p")):
        with tr.span("b", "child", ("Pod", "default", "p")):
            pass
    doc = tr.chrome_trace()
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert phases.count("X") == 2  # one complete event per span
    assert "s" in phases and "f" in phases  # the parent link draws an arrow
    assert phases.count("M") == 2  # actor lanes are named
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    assert json.load(open(path))["traceEvents"]


def test_span_tracer_degrades_on_plain_trace():
    assert span_tracer(CausalTrace()) is None
    tr = SpanTracer()
    assert span_tracer(tr) is tr


# ------------------------------------------------------------ metrics plane


def _plane(now):
    store = ResourceStore()
    coords = {"metrics": Coordinator(store, crds.METRICS)}
    return store, MetricsPlane(store, "default", coords,
                               clock=lambda: now[0])


def _pod_with_sample(job, pe_id, sample):
    pod = crds.make_pod(job, pe_id, {"image": "x"}, 1, 1)
    pod.status["metrics"] = sample
    return pod


def test_metrics_duplicate_sample_guard():
    """Unrelated pod-status patches re-deliver the last sample; appending
    the duplicate at a later t would dilute the window's computed rates."""
    now = [100.0]
    _, plane = _plane(now)
    sample = {"operator": "ch", "kind": "channel", "tuplesIn": 10}
    plane.ingest("j", 1, sample)
    now[0] += 1.0
    plane.ingest("j", 1, dict(sample))  # identical payload, later t
    assert len(plane._samples[("j", 1)]) == 1
    now[0] += 1.0
    plane.ingest("j", 1, {"operator": "ch", "kind": "channel", "tuplesIn": 30})
    assert len(plane._samples[("j", 1)]) == 2
    agg = plane.aggregate("j")
    # rate computed over the real 2 s gap, undiluted by the duplicate
    assert agg["operators"]["ch"]["rate"] == pytest.approx(10.0)


def test_metrics_retired_drop_ledger_fold():
    """A retiring PE's terminal drop count outlives its pod: the DELETED
    event folds it into the per-job ledger and aggregate() keeps it."""
    now = [100.0]
    store, plane = _plane(now)
    pod = _pod_with_sample("j", 1, {"operator": "ch", "kind": "channel",
                                    "region": "par", "tuplesDropped": 7})
    plane.on_event(Event(seq=1, type=EventType.ADDED, resource=pod))
    assert ("j", 1) in plane._samples
    plane.on_event(Event(seq=2, type=EventType.DELETED, resource=pod))
    assert ("j", 1) not in plane._samples
    assert plane._retired_drops["j"] == {"par": 7}
    agg = plane.aggregate("j")
    assert agg["tuplesDropped"] == 7
    assert agg["regions"]["par"]["tuplesDropped"] == 7


def test_metrics_job_delete_prunes_per_job_state():
    """Satellite: Job DELETED must drop the retired-drop ledger, the
    publish throttle stamp, and every sample window for that job."""
    now = [100.0]
    store, plane = _plane(now)
    pod = _pod_with_sample("j", 1, {"operator": "ch", "kind": "channel",
                                    "region": "par", "tuplesDropped": 3})
    plane.on_event(Event(seq=1, type=EventType.ADDED, resource=pod))
    plane.on_event(Event(seq=2, type=EventType.DELETED, resource=pod))
    plane.ingest("j", 2, {"operator": "sink", "kind": "sink", "tuplesIn": 5})
    plane.ingest("other", 1, {"operator": "ch", "kind": "channel"})
    plane._last_publish["j"] = 100.0
    job = crds.make_job("j", {})
    plane.on_event(Event(seq=3, type=EventType.DELETED, resource=job))
    assert "j" not in plane._retired_drops
    assert "j" not in plane._last_publish
    assert all(k[0] != "j" for k in plane._samples)
    assert ("other", 1) in plane._samples  # other jobs untouched


def test_metrics_latency_rollup_weighted_mean():
    now = [100.0]
    _, plane = _plane(now)
    plane.ingest("j", 1, {"operator": "sinkA", "kind": "sink", "region": "par",
                          "latencyP50": 10.0, "latencyP95": 20.0,
                          "latencyP99": 30.0, "latencyMax": 40.0,
                          "latencySamples": 100})
    plane.ingest("j", 2, {"operator": "sinkB", "kind": "sink", "region": "par",
                          "latencyP50": 30.0, "latencyP95": 40.0,
                          "latencyP99": 50.0, "latencyMax": 60.0,
                          "latencySamples": 300})
    agg = plane.aggregate("j")
    # sample-weighted: (100*10 + 300*30) / 400
    assert agg["latencyP50"] == pytest.approx(25.0)
    assert agg["latencyP95"] == pytest.approx(35.0)
    assert agg["latencyMax"] == pytest.approx(60.0)
    assert agg["latencySamples"] == 400
    assert agg["regions"]["par"]["latencyP50"] == pytest.approx(25.0)


# --------------------------------------------------------------- SLO judging


def test_slo_judge_dimensions():
    spec = {"latencyP95Ms": 100.0, "latencyP99Ms": None,
            "lossBudgetTuples": 5, "recoveryTimeS": 10.0}
    ok = {"p95Ms": 50.0, "p99Ms": 500.0, "lossTuples": 5, "recoveryS": 9.0,
          "latencySamples": 10, "recoveries": 1}
    assert SLOConductor.judge(spec, ok) == []  # p99 disabled; loss at budget
    assert SLOConductor.judge(spec, {**ok, "p95Ms": 101.0}) == ["latencyP95"]
    assert SLOConductor.judge(spec, {**ok, "lossTuples": 6}) == ["loss"]
    assert SLOConductor.judge(spec, {**ok, "recoveryS": 11.0}) == ["recovery"]
    # no evidence yet: every dimension passes
    empty = {"p95Ms": None, "p99Ms": None, "lossTuples": 0, "recoveryS": None,
             "latencySamples": 0, "recoveries": 0}
    assert SLOConductor.judge(spec, empty) == []


def test_slo_counts_open_recovery_spans():
    """An in-flight recovery that has already blown the bound violates NOW
    — the judge must not wait for the span to finish."""
    now = [100.0]
    store = ResourceStore()
    tr = SpanTracer(clock=lambda: now[0])
    coords = {"slo": Coordinator(store, crds.SLO),
              "metrics": Coordinator(store, crds.METRICS)}
    cond = SLOConductor(store, "default", coords, tr, clock=lambda: now[0])
    store.create(crds.make_slo("j", recovery_time_s=5.0))
    tr.start_span("chaos", "recover", ("Pod", "default", "j-pe-1"),
                  job="j", pe=1)  # never ended
    now[0] += 6.0
    obs = cond.observe("j")
    assert obs["recoveryS"] == pytest.approx(6.0)
    assert cond.evaluate("j", force=True)
    slo = store.get(crds.SLO, crds.slo_name("j"))
    conds = {c["type"]: c for c in slo.status["conditions"]}
    assert conds["Violated"]["status"] == "True"
    assert "recovery" in conds["Violated"]["reason"]
    assert slo.status["ledger"]["violations"] == 1
    assert slo.status["ledger"]["worstRecoveryS"] == pytest.approx(6.0)


def test_slo_verdict_edits_do_not_feed_back():
    """The conductor's own verdict edit raises an SLO MODIFIED event; only
    *spec* changes may force a re-evaluation, else the judge self-triggers
    an unthrottled event loop."""
    now = [100.0]
    store = ResourceStore()
    coords = {"slo": Coordinator(store, crds.SLO),
              "metrics": Coordinator(store, crds.METRICS)}
    cond = SLOConductor(store, "default", coords, clock=lambda: now[0])
    slo = crds.make_slo("j", latency_p95_ms=100.0)
    store.create(slo)
    cond.on_event(Event(seq=1, type=EventType.ADDED, resource=slo))
    first = store.get(crds.SLO, slo.name).status["ledger"]["evaluations"]
    assert first == 1  # new spec: judged immediately
    # the verdict's own MODIFIED echo, same spec, same instant: throttled
    echo = store.get(crds.SLO, slo.name)
    for seq in range(2, 12):
        cond.on_event(Event(seq=seq, type=EventType.MODIFIED, resource=echo))
    assert store.get(crds.SLO, slo.name).status["ledger"]["evaluations"] == 1
    # a genuine spec change forces a fresh verdict at the same instant
    changed = store.get(crds.SLO, slo.name)
    changed.spec = {**changed.spec, "latencyP95Ms": 50.0}
    cond.on_event(Event(seq=12, type=EventType.MODIFIED, resource=changed))
    assert store.get(crds.SLO, slo.name).status["ledger"]["evaluations"] == 2


# ------------------------------------------------- threaded acceptance runs


@pytest.mark.slow
def test_drain_renders_parented_span_chain(tmp_path):
    """Acceptance: a scale-down drain exports a parented span chain — the
    job controller's drain root with kubelet begin-drain and pod-conductor
    retire as children — and the Chrome export carries all of it."""
    p = Platform(num_nodes=4)
    try:
        p.submit("j", {"app": {"type": "streams", "width": 2,
                               "pipeline_depth": 1,
                               "source": {"rate_sleep": 0.001}},
                       "drain": {"timeout": 10.0, "grace": 0.2}})
        assert p.wait_full_health("j", 60)
        p.set_width("j", "par", 1)
        assert wait_for(lambda: p.region_width("j", "par") == 1
                        and p.job_status("j").get("fullHealth"), 60)
        assert wait_for(lambda: any(
            s.t1 is not None for s in p.trace.spans(name="drain")), 30)
        root = next(s for s in p.trace.spans(name="drain")
                    if s.t1 is not None)
        tree = {s.name for s in p.trace.spans(trace_id=root.trace_id)}
        assert {"drain", "begin-drain", "retire"} <= tree
        retire = next(s for s in p.trace.spans(name="retire")
                      if s.trace_id == root.trace_id)
        begin = next(s for s in p.trace.spans(name="begin-drain")
                     if s.trace_id == root.trace_id)
        assert begin.parent_id == root.span_id
        assert retire.parent_id == root.span_id
        assert root.attrs.get("clean") is True
        text = p.trace.render(root)
        assert "drain Pod/" in text and "\n  " in text  # indented children
        doc = json.load(open(p.export_trace(str(tmp_path / "drain.json"))))
        names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert {"drain", "begin-drain", "retire"} <= names
    finally:
        p.shutdown()


@pytest.mark.slow
def test_rebalance_renders_parented_span_chain():
    """Acceptance: a hot-node rebalance renders one migrate root owning the
    whole loss-proofed restart chain — recover under migrate, decide+bind
    and start-pod under recover."""
    p = Platform(num_nodes=1, cores_per_node=2, scheduler_profile="pressure",
                 cpu_model=True, rebalance=True, pressure_interval=0.2)
    p.rebalancer.sustain_s = 0.5
    p.rebalancer.cooldown = 1.0
    try:
        p.submit("j", {"app": {"type": "streams", "width": 2,
                               "pipeline_depth": 1,
                               "source": {"tuples": 600,
                                          "rate_sleep": 0.002},
                               "channel": {"work_sleep": 0.002},
                               "sink": {"report_every": 10}}})
        assert p.wait_full_health("j", 120)
        assert wait_for(
            lambda: p.node_pressure("node0").get("podsPerCore", 0) >= 1.0, 30)
        p.add_node("relief0", 8)
        p.add_node("relief1", 8)
        assert wait_for(lambda: p.rebalancer.migrations >= 1, 60)
        assert wait_for(lambda: any(
            s.t1 is not None for s in p.trace.spans(name="migrate")), 60)
        root = next(s for s in p.trace.spans(name="migrate")
                    if s.t1 is not None)
        family = p.trace.spans(trace_id=root.trace_id)
        names = {s.name for s in family}
        assert {"migrate", "recover", "decide+bind", "start-pod"} <= names
        recover = next(s for s in family if s.name == "recover")
        assert recover.parent_id == root.span_id
        assert {s.parent_id for s in family if s.name == "start-pod"} \
            == {recover.span_id}
        assert root.attrs.get("to", "").startswith("relief")
        assert p.wait_full_health("j", 120)
    finally:
        p.shutdown()


@pytest.mark.slow
def test_slo_verdict_over_live_job():
    """An SLO over a live job reaches Met with a populated ledger, and the
    Prometheus exposition carries latency quantiles + the verdict."""
    p = Platform(num_nodes=4)
    try:
        p.submit("j", {"app": {"type": "streams", "width": 2,
                               "pipeline_depth": 1,
                               "source": {"rate_sleep": 0.001},
                               "sink": {"report_every": 10}}})
        assert p.wait_full_health("j", 60)
        p.set_slo("j", latency_p95_ms=2000.0, loss_budget=0,
                  recovery_time_s=60.0)
        assert p.api.slos.wait_for_condition(crds.slo_name("j"),
                                             crds.COND_SLO_MET, "True", 60)
        assert wait_for(
            lambda: p.job_metrics("j").get("latencySamples", 0) > 0, 60)
        ledger = p.slo_status("j")["ledger"]
        assert ledger["evaluations"] >= 1
        assert ledger["lastVerdict"] == "Met"
        assert ledger["lossRemainingTuples"] == 0  # budget 0, nothing spent
        assert wait_for(lambda: "streams_job_delivery_latency_ms"
                        in p.metrics_text(), 30)
        text = p.metrics_text()
        assert 'streams_slo_met{job="j"} 1' in text
        assert 'quantile="0.95"' in text
    finally:
        p.shutdown()
