"""Data pipeline determinism + checkpoint store semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import CheckpointStore
from repro.data import StreamSource, batch_specs


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 2 ** 16))
def test_stream_pure_function_of_offset(offset, seed):
    src = StreamSource(vocab_size=128, batch=2, seq_len=16, seed=seed)
    a = src.batch_at(offset)
    b = src.batch_at(offset)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # labels are next-tokens
    full_a = np.concatenate([np.asarray(a["tokens"]),
                             np.asarray(a["labels"][:, -1:])], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], np.asarray(a["labels"]))


def test_stream_distinct_offsets_differ():
    src = StreamSource(vocab_size=512, batch=2, seq_len=32, seed=0)
    a, b = src.batch_at(0), src.batch_at(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_lcg_mode_is_low_entropy():
    """The learnable stream must be predictable: next token is an affine
    function of the current one ~95% of the time."""
    src = StreamSource(vocab_size=503, batch=4, seq_len=256, seed=1, mode="lcg",
                       noise=0.05)
    b = src.batch_at(0)
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    a_coef = 8121 % 503 or 13
    c = 28411 % 503
    pred = (a_coef * toks + c) % 503
    agree = (pred == labels).mean()
    assert agree > 0.85


def test_batch_specs_match_real_batches():
    src = StreamSource(vocab_size=128, batch=2, seq_len=16, seed=0,
                       frontend_len=4, frontend_dim=8)
    b = src.batch_at(0)
    specs = batch_specs(128, 2, 16, 4, 8)
    for k, spec in specs.items():
        assert b[k].shape == spec.shape, k
        assert b[k].dtype == spec.dtype, k


def test_ckpt_roundtrip_and_sweep(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    store.save_shard("job", "r", 10, "params", arrays=tree, meta={"step": 10})
    store.save_shard("job", "r", 20, "params", arrays=tree, meta={"step": 20})
    got, meta = store.load_shard("job", "r", 10, "params", like=tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert meta == {"step": 10}
    removed = store.sweep("job", "r", committed=20)
    assert removed == 1
    assert not store.has_shard("job", "r", 10, "params")
    assert store.has_shard("job", "r", 20, "params")


def test_ckpt_atomic_tmp_rename(tmp_path):
    """Writes are tmp+rename: no tmp residue after a save, and a stale tmp
    left by a crashed writer is simply overwritten by the next save."""
    import os

    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.zeros((3,), jnp.float32)}
    d = store.save_shard("job", "r", 1, "params", arrays=tree,
                         meta={"step": 1})
    names = os.listdir(d)
    assert not any(n.endswith(".tmp") for n in names), names
    assert "params.npz" in names and "params.json" in names
    # simulate a crashed writer: stale tmp + a garbage payload
    with open(os.path.join(d, ".params.npz.tmp"), "wb") as f:
        f.write(b"partial garbage")
    store.save_shard("job", "r", 1, "params", arrays=tree, meta={"step": 1})
    got, meta = store.load_shard("job", "r", 1, "params", like=tree)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    assert meta == {"step": 1}


def test_ckpt_incremental_diff_links_clean_shards(tmp_path):
    """Dirty-shard diffing: against ``base_step``, an unchanged shard is
    hard-linked (same inode) while a changed shard is rewritten."""
    import os

    store = CheckpointStore(str(tmp_path))
    clean = {"w": jnp.arange(4, dtype=jnp.float32)}
    dirty0 = {"s": jnp.zeros((2,), jnp.float32)}
    dirty1 = {"s": jnp.ones((2,), jnp.float32)}
    store.save_shard("job", "r", 10, "clean", arrays=clean,
                     meta={"step": 10})
    store.save_shard("job", "r", 10, "dirty", arrays=dirty0)
    store.save_shard("job", "r", 20, "clean", arrays=clean,
                     meta={"step": 10}, base_step=10)
    store.save_shard("job", "r", 20, "dirty", arrays=dirty1, base_step=10)
    base = store._dir("job", "r", 10)
    cur = store._dir("job", "r", 20)
    # unchanged shard: linked, not copied — one inode, two names
    st_base = os.stat(os.path.join(base, "clean.npz"))
    st_cur = os.stat(os.path.join(cur, "clean.npz"))
    assert st_base.st_ino == st_cur.st_ino
    assert st_cur.st_nlink >= 2
    assert (os.stat(os.path.join(base, "clean.json")).st_ino
            == os.stat(os.path.join(cur, "clean.json")).st_ino)
    # changed shard: rewritten — fresh inode, fresh content
    assert (os.stat(os.path.join(base, "dirty.npz")).st_ino
            != os.stat(os.path.join(cur, "dirty.npz")).st_ino)
    got, _ = store.load_shard("job", "r", 20, "dirty", like=dirty1)
    np.testing.assert_array_equal(np.asarray(got["s"]),
                                  np.asarray(dirty1["s"]))
    # the linked copy still round-trips independently of the base
    got, meta = store.load_shard("job", "r", 20, "clean", like=clean)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(clean["w"]))
    assert meta == {"step": 10}


def test_ckpt_load_at_older_step_fallback(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(3, dtype=jnp.float32)}
    store.save_shard("job", "r", 5, "pe1", arrays=tree, meta={"offset": 5})
    store.save_shard("job", "r", 9, "other", meta={"offset": 9})
    # step 9 has no pe1 shard: fall back to the newest older step that does
    step, got, meta = store.load_shard_at_or_before("job", "r", 9, "pe1",
                                                    like=tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert meta == {"offset": 5}
    # nothing at or below the requested step
    assert store.load_shard_at_or_before("job", "r", 4, "pe1") == (None, None,
                                                                   None)


def test_ckpt_sweep_spares_committing_and_newer_steps(tmp_path):
    """The sweep deletes only strictly-older unmarked steps: the step a CRD
    write is mid-commit on (``.committing``) and any newer in-flight step
    must survive."""
    store = CheckpointStore(str(tmp_path))
    for step in (10, 20, 30, 40):
        store.save_shard("job", "r", step, "params", meta={"step": step})
    store.mark_committing("job", "r", 20)
    assert store.committing("job", "r", 20)
    removed = store.sweep("job", "r", committed=30)
    # 10 reaped; 20 spared (mid-commit); 30 committed; 40 newer in-flight
    assert removed == 1
    assert store.steps("job", "r") == [20, 30, 40]
    store.clear_committing("job", "r", 20)
    assert not store.committing("job", "r", 20)
    assert store.sweep("job", "r", committed=30) == 1
    assert store.steps("job", "r") == [30, 40]


def test_ckpt_jax_pytree_roundtrip_with_scalar_meta(tmp_path):
    """Mixed-dtype jax pytrees round-trip bit-exact next to scalar metadata
    in the json sidecar."""
    store = CheckpointStore(str(tmp_path))
    tree = {"params": {"dense": jnp.linspace(0, 1, 12,
                                             dtype=jnp.float32).reshape(3, 4),
                       "bias": jnp.array([-1, 0, 7], jnp.int32)},
            "opt": [jnp.full((2, 2), 0.5, jnp.float32),
                    jnp.array(3, jnp.int32)]}
    meta = {"step": 42, "loss": 0.125, "clean": True, "tag": "warm"}
    store.save_shard("job", "r", 42, "state", arrays=tree, meta=meta)
    got, got_meta = store.load_shard("job", "r", 42, "state", like=tree)
    import jax

    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype
    assert got_meta == meta
