"""Data pipeline determinism + checkpoint store semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import CheckpointStore
from repro.data import StreamSource, batch_specs


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 2 ** 16))
def test_stream_pure_function_of_offset(offset, seed):
    src = StreamSource(vocab_size=128, batch=2, seq_len=16, seed=seed)
    a = src.batch_at(offset)
    b = src.batch_at(offset)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # labels are next-tokens
    full_a = np.concatenate([np.asarray(a["tokens"]),
                             np.asarray(a["labels"][:, -1:])], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], np.asarray(a["labels"]))


def test_stream_distinct_offsets_differ():
    src = StreamSource(vocab_size=512, batch=2, seq_len=32, seed=0)
    a, b = src.batch_at(0), src.batch_at(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_lcg_mode_is_low_entropy():
    """The learnable stream must be predictable: next token is an affine
    function of the current one ~95% of the time."""
    src = StreamSource(vocab_size=503, batch=4, seq_len=256, seed=1, mode="lcg",
                       noise=0.05)
    b = src.batch_at(0)
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    a_coef = 8121 % 503 or 13
    c = 28411 % 503
    pred = (a_coef * toks + c) % 503
    agree = (pred == labels).mean()
    assert agree > 0.85


def test_batch_specs_match_real_batches():
    src = StreamSource(vocab_size=128, batch=2, seq_len=16, seed=0,
                       frontend_len=4, frontend_dim=8)
    b = src.batch_at(0)
    specs = batch_specs(128, 2, 16, 4, 8)
    for k, spec in specs.items():
        assert b[k].shape == spec.shape, k
        assert b[k].dtype == spec.dtype, k


def test_ckpt_roundtrip_and_sweep(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    store.save_shard("job", "r", 10, "params", arrays=tree, meta={"step": 10})
    store.save_shard("job", "r", 20, "params", arrays=tree, meta={"step": 20})
    got, meta = store.load_shard("job", "r", 10, "params", like=tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert meta == {"step": 10}
    removed = store.sweep("job", "r", committed=20)
    assert removed == 1
    assert not store.has_shard("job", "r", 10, "params")
    assert store.has_shard("job", "r", 20, "params")
