"""Per-arch smoke tests (reduced configs, CPU) + attention/MoE equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config, reduced_config, SHAPES, shape_applicable
from repro.kernels import ref
from repro.models import (
    ModelOptions,
    decode_step,
    forward,
    forward_with_cache,
    init_cache,
    init_params,
    loss_fn,
)
from repro.models.layers import (
    blockwise_causal_attention,
    local_band_attention,
    tree_causal_attention,
)

OPTS = ModelOptions(compute_dtype="float32")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one grad step, shapes + no NaN."""
    cfg = reduced_config(arch)
    params = init_params(jax.random.key(0), cfg)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = (jax.random.normal(jax.random.key(2), (B, cfg.frontend_len, cfg.frontend_dim))
          if cfg.frontend else None)
    logits, aux = forward(params, cfg, toks, fe, OPTS)
    exp_s = S + (cfg.frontend_len if cfg.frontend else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    batch = {"tokens": toks, "labels": toks}
    if fe is not None:
        batch["frontend_embeds"] = fe
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, OPTS, remat=False), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ["qwen3-14b", "recurrentgemma-9b",
                                  "xlstm-125m", "gemma-2b"])
def test_prefill_decode_equivalence(arch):
    """decode_step from a prefilled cache == full forward logits."""
    cfg = reduced_config(arch)
    params = init_params(jax.random.key(0), cfg)
    B, S = 2, 128
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks, None, OPTS)
    n0 = 64
    pre, cache = forward_with_cache(params, cfg, toks[:, :n0], None,
                                    max_len=S, opts=OPTS)
    errs = [float(jnp.max(jnp.abs(pre[:, -1] - full[:, n0 - 1])))]
    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t, OPTS))
    for t in range(n0, S):
        lg, cache = step(cache, toks[:, t])
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-3, errs


def test_tree_attention_exact():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 4, 32))
    v = jax.random.normal(ks[2], (2, 256, 4, 32))
    a = blockwise_causal_attention(q, k, v, q_chunk=64, kv_chunk=64)
    b = tree_causal_attention(q, k, v, chunk=64)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(a, want, atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 256]), st.sampled_from([1, 2, 4]),
       st.sampled_from([32, 64]), st.integers(0, 2 ** 31 - 1))
def test_blockwise_attention_matches_oracle(S, H, D, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, S, H, D))
    k = jax.random.normal(ks[1], (1, S, H, D))
    v = jax.random.normal(ks[2], (1, S, H, D))
    out = blockwise_causal_attention(q, k, v, q_chunk=64, kv_chunk=64)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_local_band_matches_windowed_oracle():
    ks = jax.random.split(jax.random.key(1), 3)
    S, w = 256, 64
    q = jax.random.normal(ks[0], (2, S, 2, 32))
    k = jax.random.normal(ks[1], (2, S, 2, 32))
    v = jax.random.normal(ks[2], (2, S, 2, 32))
    out = local_band_attention(q, k, v, window=w)
    want = ref.causal_attention_ref(q, k, v, window=w)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_moe_einsum_vs_sort_dispatch():
    """Both dispatch implementations share capacity/drop semantics."""
    from repro.models.moe import init_moe, moe_apply
    from repro.configs.base import MoECfg

    m = MoECfg(num_experts=8, num_shared=2, top_k=2, d_expert=32,
               group_size=64, capacity_factor=2.0)
    d = 64
    params = init_moe(jax.random.key(0), d, m)
    x = jax.random.normal(jax.random.key(1), (2, 64, d))
    out_e, aux_e = moe_apply(params, x, m, "silu")
    m_sort = MoECfg(**{**m.__dict__, "impl": "sort"})
    out_s, aux_s = moe_apply(params, x, m_sort, "silu")
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_param_counts_close_to_published():
    """Param counts from configs should be in the right ballpark (the
    published sizes are approximate — embeddings/details vary)."""
    expected = {  # billions, generous bands
        "qwen3-14b": (12, 17), "yi-6b": (5, 7), "gemma-2b": (2, 3.2),
        "deepseek-moe-16b": (14, 19), "qwen2-moe-a2.7b": (12, 16),
        "recurrentgemma-9b": (7.5, 11), "musicgen-large": (1.5, 2.8),
        "qwen1.5-4b": (3, 5), "internvl2-26b": (18, 24),
        "xlstm-125m": (0.10, 0.22),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_shape_applicability_matrix():
    """40 cells: full-attention archs skip long_500k only."""
    total = runnable = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, why = shape_applicable(cfg, shape)
            runnable += ok
            if shape.name == "long_500k":
                assert ok == cfg.sub_quadratic
            else:
                assert ok
    assert total == 40 and runnable == 32  # 8 archs skip long_500k; 2 run it
