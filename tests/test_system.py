"""System-level behaviour: the paper's test-harness style scenarios (§6.6) —
submit, probe for states, randomly kill critical processes, assert recovery.
Also covers the legacy-platform baseline used in benchmarks."""

import time

import pytest

from repro.core import wait_for
from repro.platform import Platform, crds
from repro.platform.legacy import LegacyPlatform


def test_scenario_kill_random_pes_streams():
    """Paper §6.6: 'randomly killing critical processes' — the app must
    return to full health after each kill and keep processing.  The kills
    ride the chaos plane's scenario harness (seeded FaultInjection records
    executed by the ChaosConductor), so each round is a replayable record
    with its own recovery verdict, not a raw side-door kill.

    Budgeted for degraded timers (sub-ms sleeps cost up to ~10 ms under
    suite load): the source is throttled at 5 ms — comfortably above the
    container's sleep-granularity floor, so the job's CPU load stays light
    and bounded whatever the timer does — and the recovery waits budget
    the restart chain at suite-load speed, not isolation speed."""
    p = Platform(num_nodes=4)
    try:
        p.submit("chaos", {"app": {"type": "streams", "width": 2,
                                   "pipeline_depth": 2,
                                   "source": {"rate_sleep": 0.005}}})
        assert p.wait_full_health("chaos", 120)
        n_pes = len(p.pods("chaos"))
        for round_ in range(3):
            st = p.run_scenario(fault="pod-kill", job="chaos", seed=round_,
                                tag=f"kill-{round_}",
                                target={"minPe": 1},  # keep the source alive
                                params={"recoveryTimeout": 120.0},
                                timeout=150)
            assert st["completed"], f"no recovery in round {round_}: {st}"
            assert 1 <= st["chosen"]["pe"] < n_pes
            assert p.wait_full_health("chaos", 120), \
                f"no full health after pe {st['chosen']['pe']}"

        def sink_seen():
            for x in p.pods("chaos"):
                if x.status.get("sink"):
                    return x.status["sink"]["seen"]
            return 0

        before = sink_seen()
        assert wait_for(lambda: sink_seen() > before, 30)
        p.delete_job("chaos")
        assert p.wait_terminated("chaos", 30)
    finally:
        p.shutdown()


def test_consistent_region_at_least_once(tmp_path):
    """Kill the source of a consistent region: after recovery the sink must
    have seen every sequence number at least once (duplicates allowed)."""
    p = Platform(num_nodes=4, ckpt_root=str(tmp_path / "ckpt"))
    try:
        p.submit("cr-app", {
            "app": {"type": "streams", "width": 1, "pipeline_depth": 1,
                    "source": {"rate_sleep": 0.002}},
            "consistentRegion": {"name": "region", "interval": 50,
                                 "operators": ["src"]},
        })
        assert p.wait_full_health("cr-app", 60)
        assert p.wait_cr_committed("cr-app", "region", 50, 60)
        p.kill_pod("cr-app", 0)  # kill the source
        assert p.wait_full_health("cr-app", 90)
        assert p.wait_cr_committed("cr-app", "region", 100, 90)

        def sink():
            for x in p.pods("cr-app"):
                if x.status.get("sink"):
                    return x.status["sink"]
            return None

        assert wait_for(lambda: (sink() or {}).get("maxseq", -1) >= 150, 60)
        s = sink()
        # at-least-once: seen count >= distinct sequence numbers (replays
        # after rollback produce duplicates, never gaps)
        assert s["seen"] >= s["maxseq"] * 0.9
    finally:
        p.shutdown()


def test_legacy_platform_parity_smoke():
    lp = LegacyPlatform(num_nodes=4, zk_op_cost=0.0)
    try:
        lp.submit("l1", {"app": {"type": "streams", "width": 2,
                                 "pipeline_depth": 2,
                                 "source": {"tuples": 200}}})
        assert wait_for(lambda: lp.full_health("l1"), 30)
        assert wait_for(lambda: any(s["seen"] >= 200 for s in lp.sinks.values()),
                        60)
        lp.change_width("l1", "par", 4)
        assert len(lp.plans["l1"].pes) > 0
        lp.cancel("l1")
        assert not any(j == "l1" for (j, _) in lp.pes)
    finally:
        lp.shutdown()


def test_legacy_kill_pe_recovers():
    lp = LegacyPlatform(num_nodes=4, zk_op_cost=0.0)
    try:
        lp.submit("l2", {"app": {"type": "streams", "width": 2,
                                 "pipeline_depth": 1,
                                 "source": {"rate_sleep": 0.001}}})
        assert wait_for(lambda: lp.full_health("l2"), 30)
        assert lp.kill_pe("l2", 2)
        assert wait_for(lambda: lp.full_health("l2"), 60)
        lp.cancel("l2")
    finally:
        lp.shutdown()
