"""Wire-format properties: the frame codec under adversarial byte streams.

The socket transport's correctness rests on three invariants this file
attacks directly:

- **round-trip fidelity**: any value shape the platform ships (tuple
  batches of nested dicts/lists with payload bytes, big ints, floats)
  decodes to an equal structure;
- **split-safety**: the incremental decoder yields identical frames no
  matter where the kernel splits the byte stream — including mid-header
  and one-byte-at-a-time;
- **truncation discipline**: a stream that dies mid-frame (or lies about
  its length) surfaces a transport error — ``Unreachable`` at the sender,
  a discarded connection at the hub — never a half-decoded batch in a
  ring.
"""

import random
import socket
import threading
import time

import pytest

from _hypothesis_compat import given, settings, st
from repro.platform.transport import (
    SocketHub,
    SocketSender,
    SocketTupleQueue,
    TupleQueue,
    Unreachable,
)
from repro.platform.wire import (
    DEFAULT_MAX_FRAME,
    F_ACK,
    F_DATA,
    HEADER,
    HEADER_SIZE,
    MAGIC,
    FrameDecoder,
    FrameError,
    TruncatedFrame,
    decode_value,
    encode_frame,
    encode_value,
)

pytestmark = pytest.mark.transport


# ------------------------------------------------------- value generation

def _rand_value(rng: random.Random, depth: int = 0):
    """An arbitrary codec-shaped value: the tuple-batch alphabet."""
    kinds = ["none", "bool", "int", "bigint", "float", "str", "bytes"]
    if depth < 3:
        kinds += ["list", "tuple", "dict"] * 2
    kind = rng.choice(kinds)
    if kind == "none":
        return None
    if kind == "bool":
        return rng.random() < 0.5
    if kind == "int":
        return rng.randint(-(2 ** 63), 2 ** 63 - 1)
    if kind == "bigint":
        return rng.randint(2 ** 70, 2 ** 90) * (-1 if rng.random() < 0.5 else 1)
    if kind == "float":
        return rng.choice([0.0, -1.5, 3.141592653589793, 1e300, -1e-300,
                           float(rng.randint(-10 ** 6, 10 ** 6))])
    if kind == "str":
        return "".join(rng.choice("aé∆b∑c𝕊d \n\"'\\x00") for _ in
                       range(rng.randint(0, 12)))
    if kind == "bytes":
        return bytes(rng.getrandbits(8) for _ in range(rng.randint(0, 64)))
    n = rng.randint(0, 4)
    if kind == "list":
        return [_rand_value(rng, depth + 1) for _ in range(n)]
    if kind == "tuple":
        return tuple(_rand_value(rng, depth + 1) for _ in range(n))
    return {str(rng.randint(0, 99)) if rng.random() < 0.7
            else rng.randint(0, 99): _rand_value(rng, depth + 1)
            for _ in range(n)}


def _norm(v):
    """Collapse memoryview (the zero-copy decode of bytes) for comparison."""
    if isinstance(v, memoryview):
        return bytes(v)
    if isinstance(v, list):
        return [_norm(x) for x in v]
    if isinstance(v, tuple):
        return tuple(_norm(x) for x in v)
    if isinstance(v, dict):
        return {k: _norm(x) for k, x in v.items()}
    return v


# ------------------------------------------------------------- round trip

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_roundtrip_arbitrary_tuple_batches(seed):
    rng = random.Random(seed)
    batch = tuple({"seq": i, "ts": rng.random(),
                   "v": _rand_value(rng)} for i in range(rng.randint(0, 8)))
    assert _norm(decode_value(encode_value(batch))) == _norm(batch)


def test_roundtrip_scalar_edges():
    for v in (None, True, False, 0, -1, 2 ** 63 - 1, -(2 ** 63), 2 ** 200,
              -(2 ** 200), 0.0, float("inf"), float("-inf"), "", "héllo",
              b"", b"\x00\xff" * 100, [], (), {}, {"k": (1, [b"x", None])}):
        assert _norm(decode_value(encode_value(v))) == _norm(v)


def test_bytes_decode_zero_copy_into_receive_buffer():
    payload = encode_value({"payload": b"A" * 1024})
    out = decode_value(payload)
    view = out["payload"]
    assert isinstance(view, memoryview) and bytes(view) == b"A" * 1024
    # the view aliases the wire buffer — no per-payload copy on receive
    assert view.obj is payload or isinstance(view.obj, (bytes, memoryview))


# ------------------------------------------------------------ split-safety

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31))
def test_decoder_reassembles_at_random_split_boundaries(seed):
    rng = random.Random(seed)
    frames = [encode_frame(F_DATA, encode_value(
        (i, "ep1", "put_many", 1.0, [_rand_value(rng)])))
        for i in range(3)]
    stream = b"".join(frames)
    cuts = sorted(rng.randint(0, len(stream)) for _ in range(rng.randint(0, 6)))
    chunks, prev = [], 0
    for c in cuts + [len(stream)]:
        chunks.append(stream[prev:c])
        prev = c
    dec = FrameDecoder()
    got = []
    for chunk in chunks:
        got.extend(dec.feed(chunk))
    dec.eof()  # clean boundary: nothing pending
    assert [bytes(p) for _, p in got] == \
        [f[HEADER_SIZE:] for f in frames]


def test_decoder_survives_every_single_byte_boundary():
    """The exhaustive version: one frame fed byte-at-a-time must produce
    exactly one frame, completed precisely at the final byte."""
    frame = encode_frame(F_DATA, encode_value(("x", [1, 2.5, b"pp"])))
    dec = FrameDecoder()
    outs = []
    for i, b in enumerate(frame):
        done = dec.feed(bytes([b]))
        outs.extend(done)
        if i < len(frame) - 1:
            assert done == []
    assert len(outs) == 1
    assert decode_value(outs[0][1]) == ("x", [1, 2.5, memoryview(b"pp")])


def test_decoder_payload_views_stay_valid_across_feeds():
    f1 = encode_frame(F_DATA, encode_value(b"first"))
    f2 = encode_frame(F_DATA, encode_value(b"second"))
    dec = FrameDecoder()
    (t1, p1), = dec.feed(f1 + f2[:3])
    dec.feed(f2[3:])  # must not invalidate p1's buffer
    assert bytes(decode_value(p1)) == b"first"


# ----------------------------------------------------- oversize / corrupt

def test_oversized_frame_rejected_on_encode_and_decode():
    with pytest.raises(FrameError):
        encode_frame(F_DATA, b"x" * 100, max_frame=64)
    # a header lying about an oversized body is rejected before buffering
    hdr = HEADER.pack(MAGIC, F_DATA, 0, DEFAULT_MAX_FRAME + 1)
    with pytest.raises(FrameError):
        FrameDecoder().feed(hdr)


def test_bad_magic_rejected():
    with pytest.raises(FrameError):
        FrameDecoder().feed(HEADER.pack(0xDEAD, F_DATA, 0, 0))


def test_truncated_stream_raises_on_eof_not_before():
    frame = encode_frame(F_ACK, encode_value((1, "ok", -1, "")))
    dec = FrameDecoder()
    assert dec.feed(frame[:-1]) == []  # waiting, not failing
    assert dec.pending == len(frame) - 1
    with pytest.raises(TruncatedFrame):
        dec.eof()


def test_corrupt_codec_inside_valid_frame_rejected():
    dec = FrameDecoder()
    (_, payload), = dec.feed(encode_frame(F_DATA, b"\xffgarbage"))
    with pytest.raises(FrameError):
        decode_value(payload)


# ------------------------------------- truncation at the transport layer

def test_hub_discards_partial_frame_no_half_decoded_batch():
    """A producer that dies mid-frame must contribute nothing: the hub
    discards the torn tail whole — the ring never sees a partial batch."""
    hub = SocketHub()
    try:
        ring = TupleQueue(maxsize=64)
        token = hub.register(ring)
        frame = encode_frame(F_DATA, encode_value(
            (1, token, "put_many", 1.0, [{"seq": i} for i in range(10)])))
        conn = socket.create_connection(hub.address, timeout=2.0)
        conn.sendall(frame[:len(frame) // 2])  # die mid-batch
        conn.close()
        time.sleep(0.1)
        assert len(ring) == 0 and ring.enqueued == 0
        # the hub itself is unharmed: a well-formed sender still delivers
        q = SocketTupleQueue(maxsize=64, hub=hub)
        q.put_many([{"seq": i} for i in range(5)])
        assert q.get_many(10) == [{"seq": i} for i in range(5)]
        q.close()
    finally:
        hub.close()


def test_sender_surfaces_unreachable_on_truncated_ack():
    """The receiving side of the sender: an ACK stream that dies mid-frame
    (or mid-payload) is ``Unreachable`` — never a garbled verdict."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)

    def half_acking_server():
        conn, _ = srv.accept()
        conn.recv(65536)  # swallow the request
        ack = encode_frame(F_ACK, encode_value((1, "ok", -1, "")))
        conn.sendall(ack[:len(ack) - 4])  # truncate inside the payload
        conn.close()

    th = threading.Thread(target=half_acking_server, daemon=True)
    th.start()
    sender = SocketSender(srv.getsockname(), "ep1")
    try:
        with pytest.raises(Unreachable):
            sender.put({"seq": 0}, timeout=1.0)
    finally:
        sender.dispose()
        srv.close()
        th.join(timeout=2.0)


def test_sender_unreachable_when_nobody_listens():
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    addr = srv.getsockname()
    srv.close()  # nothing listens here any more
    sender = SocketSender(addr, "ep1")
    with pytest.raises(Unreachable):
        sender.put({"seq": 0}, timeout=0.5)
    sender.dispose()


def test_interleaved_truncation_only_kills_the_torn_connection():
    """Two producers interleave on one hub; one tears mid-frame.  The torn
    one is discarded whole, the healthy one's batches all land."""
    hub = SocketHub()
    try:
        ring = TupleQueue(maxsize=256)
        token = hub.register(ring)
        healthy = SocketTupleQueue(maxsize=256, hub=hub)
        torn = socket.create_connection(hub.address, timeout=2.0)
        frame = encode_frame(F_DATA, encode_value(
            (9, token, "put_many", 1.0, [{"x": "torn"}] * 8)))
        torn.sendall(frame[:HEADER_SIZE + 3])  # header + a sliver of body
        for i in range(20):
            healthy.put({"seq": i})
        torn.close()
        time.sleep(0.1)
        got = healthy.get_many(100)
        assert [t["seq"] for t in got] == list(range(20))
        assert ring.enqueued == 0  # not one torn tuple surfaced
        healthy.close()
    finally:
        hub.close()
